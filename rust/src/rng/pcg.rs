//! PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-low + random-rotate
//! output permutation.

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;
const INC: u128 = 0x5851_f42d_4c95_7f2d_1405_7b7e_f767_814f;

/// The generator. Construct with [`Pcg64::seed`]; state advances with every
/// `next_u64` call. `spare` caches the second output of the polar normal
/// transform (see `rng/mod.rs`).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    pub(crate) spare: Option<f64>,
}

impl Pcg64 {
    /// Seed the generator. Two warm-up steps decorrelate low-entropy seeds
    /// (0, 1, 2, ...) which experiments commonly use.
    pub fn seed(seed: u64) -> Self {
        let mut g = Self {
            state: (seed as u128).wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ INC,
            spare: None,
        };
        g.next_u64();
        g.next_u64();
        g
    }

    /// Derive an independent stream, e.g. one per compression job. The child
    /// is seeded from the parent's output so parent and child streams do not
    /// overlap in practice.
    pub fn split(&mut self) -> Self {
        Self::seed(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(INC);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_streams_diverge() {
        let mut parent = Pcg64::seed(123);
        let mut a = parent.split();
        let mut b = parent.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn full_range_output() {
        // Sanity: outputs should cover high and low halves of u64.
        let mut rng = Pcg64::seed(77);
        let mut hi = false;
        let mut lo = false;
        for _ in 0..1000 {
            let x = rng.next_u64();
            hi |= x > u64::MAX / 2;
            lo |= x < u64::MAX / 2;
        }
        assert!(hi && lo);
    }
}
