//! Deterministic pseudo-random number generation.
//!
//! The whole reproduction pipeline (synthetic weight fabrication, random
//! rotations, corpus generation, property tests) must be bit-reproducible
//! across runs, so every consumer takes an explicit [`Pcg64`] seeded from the
//! experiment configuration rather than ambient OS entropy.
//!
//! `Pcg64` is the PCG-XSL-RR 128/64 generator (O'Neill, 2014): a 128-bit LCG
//! with an output permutation. It is small, fast, and passes BigCrush — more
//! than adequate for Monte-Carlo style workloads.

mod pcg;

pub use pcg::Pcg64;

/// Derive the seed of an independent per-item stream from a base seed and
/// a stream index (SplitMix64 finalizer over a golden-ratio offset).
///
/// The compression pipeline seeds layer `k` from
/// `derive_seed(base, k)`-style calls instead of advancing one shared
/// generator across the layer loop, so a layer's factors never depend on
/// how many layers precede it — the property that lets
/// `compress --jobs N` produce byte-identical artifacts for any worker
/// count, and lets jobs run in any scheduling order.
pub fn derive_seed(base: u64, stream: u64) -> u64 {
    let mut z = base
        .wrapping_add(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(stream.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Sampling extensions over a raw generator.
impl Pcg64 {
    /// Uniform `f64` in `[0, 1)` using the top 53 bits.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift rejection.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Rejection sampling to remove modulo bias.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let m = (r as u128) * (bound as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Standard normal via the Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Standard normal as `f32`.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with i.i.d. standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// Fill a slice with uniform `[lo, hi)` values.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.uniform_f32();
        }
    }

    /// Random sign in `{-1.0, +1.0}`.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Zipf-distributed integer in `[0, n)` with exponent `s`, via inverse-CDF
    /// over precomputed cumulative weights (caller should cache
    /// [`ZipfSampler`] for hot loops; this is the convenience path).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        ZipfSampler::new(n, s).sample(self)
    }
}

/// Cached inverse-CDF sampler for a Zipf distribution over `[0, n)`.
///
/// Used by the synthetic corpus generator (`data::corpus`) so that token
/// frequencies follow the heavy-tailed statistics of natural text.
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "zipf over empty support");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        self.quantile(rng.uniform())
    }

    /// Inverse CDF: the token whose cumulative mass first reaches `u`.
    /// Exposed so deterministic hash-derived uniforms (the corpus
    /// generator's context structure) can share the Zipf marginal.
    pub fn quantile(&self, u: f64) -> usize {
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).expect("cdf is finite"))
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_streams_are_distinct_and_stable() {
        // Stable for fixed inputs…
        assert_eq!(derive_seed(42, 7), derive_seed(42, 7));
        // …distinct across streams and bases (spot-check collisions).
        let mut seen = std::collections::HashSet::new();
        for base in [0u64, 1, 42, u64::MAX] {
            for stream in 0..64u64 {
                assert!(seen.insert(derive_seed(base, stream)), "collision at {base}/{stream}");
            }
        }
        // Generators from adjacent streams diverge immediately.
        let mut a = Pcg64::seed(derive_seed(42, 0));
        let mut b = Pcg64::seed(derive_seed(42, 1));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_streams_for_distinct_seeds() {
        let mut a = Pcg64::seed(1);
        let mut b = Pcg64::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::seed(7);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_unbiased_over_small_bound() {
        let mut rng = Pcg64::seed(3);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let mut rng = Pcg64::seed(9);
        let sampler = ZipfSampler::new(64, 1.1);
        let mut counts = [0u32; 64];
        for _ in 0..100_000 {
            counts[sampler.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[8]);
        assert!(counts[8] > counts[50]);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg64::seed(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
