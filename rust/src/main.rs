//! `littlebit2` — CLI for the LittleBit-2 reproduction.
//!
//! Subcommands (hand-rolled parsing; no clap in this offline build):
//!
//! ```text
//! littlebit2 memory-table [--model NAME]         Table 1/2 Mem columns (exact)
//! littlebit2 breakeven [--size N] [--bpp B]      Fig 6 top: MSE vs γ sweep
//! littlebit2 gamma-dist [--model NAME]           Fig 6 bottom / Fig 11/12
//! littlebit2 spectral-gain                       Fig 9 energy curves
//! littlebit2 compress [--method M] [--size N] [--gamma G] [--bpp B]
//!                     [--strategy S] [--layers L] [--jobs N]
//!                     [--out model.lb2] [--aligned 1]  quantize once → artifact
//!                                                      (byte-identical for any --jobs;
//!                                                       --aligned 1: v3 mmap-servable;
//!                                                       M: littlebit2|onebit|rtn|billm|arb|tinyrank)
//! littlebit2 serve --model model.lb2 [--workers N] [--batch B]
//!                  [--threads T] [--requests R]        serve from an artifact,
//!                  [--listen ADDR] [--serve-secs S]     dispatching on its METHOD tags;
//!                  [--deadline-ms D] [--max-wait-ms W]  with --listen: TCP front-end
//!                  [--chaos-seed S] [--mmap 1]          (cross-connection batching;
//!                                                      chaos-seed injects seeded faults;
//!                                                      mmap 1: zero-copy page-cache load)
//! littlebit2 client --connect HOST:PORT --width D [--requests R]
//!                   [--concurrency C] [--deadline-ms D] [--verify 1]
//!                   [--stats 1] [--shutdown 1]          wire-protocol load client
//!                   [--retries N] [--backoff-ms B] [--budget-ms T]
//!                                                      (retries>0: self-healing client)
//! littlebit2 tracker --model model.lb2 [--peers N] [--mode pipeline|rowshard]
//!                    [--listen ADDR] [--serve-secs S]  cluster tracker: loads only
//!                    [--heartbeat-ms H] [--attempts A]  the shape table, shards the
//!                    [--deadline-ms D]                  chain over JOINed peers, and
//!                                                      fronts them for `client`
//! littlebit2 peer --model model.lb2 --tracker HOST:PORT [--listen ADDR]
//!                 [--mmap 1] [--serve-secs S]          cluster peer: loads only its
//!                 [--heartbeat-ms H]                    assigned layer range (pipeline)
//!                                                      or row shard of every layer
//! littlebit2 eval [--size N] [--blocks B] [--methods CSV] [--bpp-list CSV]
//!                 [--jobs N] [--requests R] [--out BENCH_methods.json]
//!                                                      methods × bpp fidelity/
//!                                                      throughput sweep (Table 1 shape)
//! littlebit2 train [--artifacts DIR] [--teacher-steps N] [--student-steps N]
//!                  [--variant V] [--lr LR]       e2e QAKD driver
//! littlebit2 version
//! ```

use anyhow::{bail, Context, Result};
use littlebit2::artifact::StackStreamWriter;
use littlebit2::cluster::{Peer, PeerConfig, ShardMode, Tracker, TrackerConfig};
#[cfg(feature = "xla")]
use littlebit2::coordinator::{QatDriver, StudentVariant};
use littlebit2::coordinator::{
    run_compression_jobs_streaming, CompressionJob, InferenceServer, JobInput, MethodStackBackend,
    ServerConfig,
};
use littlebit2::faults::{ChaosBackend, FaultPlan, FaultSpec};
use littlebit2::littlebit::{compress, CompressionConfig, CompressionReport, InitStrategy};
use littlebit2::memory::{model_memory, MethodKind};
use littlebit2::model::{zoo, ArchSpec, MethodStack, MethodStackLayer};
use littlebit2::quant::{tiny_rank_fp16, MethodSpec, METHOD_NAMES};
use littlebit2::rng::{derive_seed, Pcg64};
use littlebit2::serving::{
    payload_f32, FrameKind, RetryPolicy, RetryingClient, ServingConfig, TcpFrontend, WireClient,
};
use littlebit2::spectral::{
    estimate_gamma, quant_cost, synth_weight, tail_energy, SynthSpec,
};
use std::sync::Arc;
use std::time::Duration;

/// Minimal flag parser: `--key value` pairs after the subcommand. Shared by
/// every subcommand, including `compress`/`serve`. A flag immediately
/// followed by another flag (`--size --bpp 0.8`) is an error, not a value,
/// and so is repeating a flag — both used to be swallowed silently.
struct Args {
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = std::collections::HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = &argv[i];
            if let Some(name) = k.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare \"--\" is not a flag");
                }
                let value = match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => v.clone(),
                    Some(v) => bail!("flag --{name} missing value (found flag {v:?} instead)"),
                    None => bail!("flag --{name} missing value"),
                };
                if flags.insert(name.to_string(), value).is_some() {
                    bail!("duplicate flag --{name}");
                }
                i += 2;
            } else {
                bail!("unexpected argument {k:?}");
            }
        }
        Ok(Self { flags })
    }

    /// Reject flags the subcommand never reads — a typo like `--ouy` must
    /// fail loudly, not silently run without the intended effect.
    fn known(&self, allowed: &[&str]) -> Result<&Self> {
        for key in self.flags.keys() {
            if !allowed.contains(&key.as_str()) {
                bail!("unknown flag --{key}; expected one of: {allowed:?}");
            }
        }
        Ok(self)
    }

    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "memory-table" => cmd_memory_table(&args),
        "breakeven" => cmd_breakeven(&args),
        "gamma-dist" => cmd_gamma_dist(&args),
        "spectral-gain" => cmd_spectral_gain(&args),
        "compress" => cmd_compress(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "tracker" => cmd_tracker(&args),
        "peer" => cmd_peer(&args),
        "eval" => cmd_eval(&args),
        "train" => cmd_train(&args),
        "version" => {
            println!("littlebit2 {}", littlebit2::VERSION);
            Ok(())
        }
        other => {
            print_usage();
            bail!("unknown command {other:?}")
        }
    }
}

fn print_usage() {
    println!(
        "littlebit2 {} — sub-1-bit LLM compression via Latent Geometry Alignment\n\
         commands: memory-table | breakeven | gamma-dist | spectral-gain | compress | serve | client | tracker | peer | eval | train | version",
        littlebit2::VERSION
    );
}

/// Table 1/2 memory columns, computed exactly from Eqs. 21-26.
fn cmd_memory_table(args: &Args) -> Result<()> {
    args.known(&["model"])?;
    let models = match args.flags.get("model") {
        Some(m) => vec![m.clone()],
        None => ArchSpec::KNOWN.iter().map(|s| s.to_string()).collect(),
    };
    let methods = [
        MethodKind::Fp16,
        MethodKind::Rtn { k: 2, group: 128 },
        MethodKind::Billm,
        MethodKind::Arb,
        MethodKind::OneBit,
        MethodKind::LittleBit { bpp: 1.0 },
        MethodKind::LittleBit { bpp: 0.55 },
        MethodKind::LittleBit { bpp: 0.1 },
        MethodKind::TinyRank { bpp: 0.1 },
    ];
    for name in models {
        let Some(arch) = ArchSpec::by_name(&name) else {
            bail!("unknown model {name:?}; known: {:?}", ArchSpec::KNOWN)
        };
        println!(
            "\n=== {} (total params {:.2}B) ===",
            arch.name,
            arch.total_params() as f64 / 1e9
        );
        println!("{:<24} {:>10} {:>8} {:>10} {:>8}", "method", "body GB", "%", "total GB", "%");
        for m in methods {
            let mm = model_memory(&arch, m);
            println!(
                "{:<24} {:>10.2} {:>7.1}% {:>10.2} {:>7.1}%",
                mm.method,
                mm.body_gb(),
                mm.body_pct(),
                mm.total_gb(),
                mm.total_pct()
            );
        }
    }
    Ok(())
}

/// Fig 6 (top): reconstruction MSE vs γ for the four methods at fixed budget.
fn cmd_breakeven(args: &Args) -> Result<()> {
    args.known(&["size", "bpp", "itq-iters"])?;
    let size = args.get_usize("size", 512)?;
    let bpp = args.get_f64("bpp", 1.0)?;
    let itq_iters = args.get_usize("itq-iters", 50)?;
    println!("size={size} bpp={bpp}");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12}",
        "gamma", "tinyrank-fp", "littlebit", "lb+rot", "littlebit2"
    );
    for g10 in 1..=8 {
        let gamma = g10 as f64 / 10.0;
        let mut rng = Pcg64::seed(7000 + g10);
        let spec = SynthSpec { rows: size, cols: size, gamma, coherence: 0.7, scale: 1.0 };
        let w = synth_weight(&spec, &mut rng);

        let r_fp = littlebit2::memory::tiny_rank_for_budget(size, size, bpp);
        let fp = tiny_rank_fp16(&w, r_fp, &mut rng).reconstruction.mse(&w);

        let mse = |strategy: InitStrategy| -> f64 {
            let mut rng = Pcg64::seed(9000 + g10);
            let cfg = CompressionConfig { bpp, strategy, residual: true, ..Default::default() };
            compress(&w, &cfg, &mut rng).reconstruct().mse(&w)
        };
        println!(
            "{gamma:>6.2} {fp:>12.4e} {:>12.4e} {:>12.4e} {:>12.4e}",
            mse(InitStrategy::Standard),
            mse(InitStrategy::RandomRotation),
            mse(InitStrategy::JointItq { iters: itq_iters }),
        );
    }
    Ok(())
}

/// Fig 6 bottom / Fig 11/12: γ distribution over a synthetic-LLM zoo.
fn cmd_gamma_dist(args: &Args) -> Result<()> {
    args.known(&["model", "blocks"])?;
    let model = args.get("model", "llama2-7b");
    let blocks = args.get_usize("blocks", 8)?;
    let Some(arch) = ArchSpec::by_name(&model) else {
        bail!("unknown model {model:?}")
    };
    let layers = zoo::fabricate(&arch, 32, blocks, 11);
    let mut rng = Pcg64::seed(3);
    println!("{:<12} {:>8} {:>10}", "module", "gamma*", "gamma-fit");
    let mut all = Vec::new();
    for l in &layers {
        let rank = l.weight.rows().min(l.weight.cols()).min(96);
        let svd = littlebit2::linalg::svd_randomized(&l.weight, rank, 10, 3, &mut rng);
        let fit = estimate_gamma(&svd.s);
        println!("b{}.{:<9} {:>8.3} {:>10.3}", l.block, l.proj.name(), l.gamma, fit.gamma);
        all.push(fit.gamma);
    }
    all.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
    let q = |p: f64| all[((all.len() - 1) as f64 * p) as usize];
    println!(
        "\nγ quantiles: p5={:.3} median={:.3} p95={:.3}  (paper Fig 11: medians 0.26-0.33, 90% in [0.19,0.47])",
        q(0.05),
        q(0.5),
        q(0.95)
    );
    Ok(())
}

/// Fig 9: tail-gain vs quantization-cost curves.
fn cmd_spectral_gain(args: &Args) -> Result<()> {
    args.known(&["n", "ra", "rb"])?;
    let n = args.get_f64("n", 4096.0)?;
    let r_a = args.get_f64("ra", 16.0)?;
    let r_b = args.get_f64("rb", 256.0)?;
    println!("n={n} r_A={r_a} r_B={r_b}");
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>14}",
        "gamma", "tail-gain", "cost(Λ=0.7)", "cost(Λ=0.36)", "cost(Λ=0.30)"
    );
    for g10 in 1..=10 {
        let gamma = g10 as f64 / 10.0;
        let gain = tail_energy(gamma, r_a, n) - tail_energy(gamma, r_b, n);
        println!(
            "{gamma:>6.2} {gain:>14.4} {:>14.4} {:>14.4} {:>14.4}",
            quant_cost(gamma, 0.7, r_b),
            quant_cost(gamma, 0.36, r_b),
            quant_cost(gamma, 0.30, r_b)
        );
    }
    for lambda in [0.7, 0.36, 0.30] {
        let be = littlebit2::spectral::break_even_gamma(lambda, r_a, r_b, n);
        println!("Λ={lambda:.2} ⇒ γ* = {:.3}", be.gamma_star);
    }
    Ok(())
}

/// Compress a synthetic model (a chain of `--layers` square weights) with
/// any registered `--method` on `--jobs N` parallel claim-loops, streaming
/// each finished layer straight into the `.lb2` v2 artifact
/// (`--out model.lb2`) — the quantize-once half of the
/// quantize-once/serve-from-many pipeline (`serve` is the other half).
/// Layer k's weight and compression each run on independent derived RNG
/// streams, so the artifact bytes are identical for any `--jobs` value
/// (and layer k never depends on how many layers precede it). For the
/// littlebit pipeline the per-stage wall-clock (svd/itq/svid/pack) is
/// reported at the end.
fn cmd_compress(args: &Args) -> Result<()> {
    args.known(&["method", "size", "layers", "gamma", "bpp", "strategy", "out", "jobs", "aligned"])?;
    let method_name = args.get("method", "littlebit2");
    let size = args.get_usize("size", 512)?;
    let layers = args.get_usize("layers", 1)?;
    let gamma = args.get_f64("gamma", 0.27)?;
    let bpp = args.get_f64("bpp", 0.55)?;
    let jobs_n = args.get_usize("jobs", 1)?;
    // --aligned 1: emit format v3, whose bit-plane payloads sit 32-byte
    // aligned at their in-memory stride so `serve --mmap` can borrow the
    // page cache directly (costs a few pad bytes per section on disk).
    let aligned = matches!(args.get("aligned", "0").as_str(), "1" | "true");
    let strategy = match args.get("strategy", "itq").as_str() {
        "standard" => InitStrategy::Standard,
        "rotation" => InitStrategy::RandomRotation,
        "itq" => InitStrategy::JointItq { iters: 50 },
        other => bail!("strategy must be standard|rotation|itq, got {other:?}"),
    };
    if layers == 0 {
        bail!("--layers must be at least 1");
    }
    if jobs_n == 0 {
        bail!("--jobs must be at least 1");
    }
    let method = MethodSpec::parse(&method_name, bpp, strategy)?;
    // Fixed-rate methods (onebit/rtn/billm/arb) never consume the bpp
    // budget; don't echo a knob that had no effect.
    let budgeted = method.is_budgeted();
    let spec = SynthSpec { rows: size, cols: size, gamma, coherence: 0.7, scale: 1.0 };

    // Per-layer derived streams: stream 2k fabricates layer k's weight,
    // stream 2k+1 drives its compression. (The old CLI advanced one shared
    // generator across the layer loop, so a layer's factors depended on
    // how many layers preceded it — and could never parallelize.)
    const BASE_SEED: u64 = 42;
    let jobs: Vec<CompressionJob> = (0..layers)
        .map(|k| CompressionJob {
            name: format!("layer{k}"),
            input: JobInput::Synth {
                spec: spec.clone(),
                seed: derive_seed(BASE_SEED, 2 * k as u64),
            },
            method: method.clone(),
            seed: derive_seed(BASE_SEED, 2 * k as u64 + 1),
        })
        .collect();
    let shapes: Vec<(usize, usize, usize)> = jobs
        .iter()
        .map(|j| {
            let (d_out, d_in) = j.shape();
            (d_in, d_out, j.n_paths())
        })
        .collect();
    let mut writer = match args.flags.get("out") {
        Some(out) if aligned => Some(StackStreamWriter::create_aligned(out, &shapes)?),
        Some(out) => Some(StackStreamWriter::create(out, &shapes)?),
        None => None,
    };

    let t0 = std::time::Instant::now();
    let mut stages = CompressionReport::default();
    let mut packed_bytes = 0usize;
    run_compression_jobs_streaming(jobs, jobs_n, |idx, outcome| {
        if idx == 0 {
            let lambda = match (outcome.result.lambda_mean, outcome.result.lambda_max) {
                (Some(m), Some(x)) => format!(" λ_mean={m:.3} λ_max={x:.3}"),
                _ => String::new(),
            };
            let budget = if budgeted { format!(" bpp={bpp}") } else { String::new() };
            println!(
                "method={} size={size} γ={gamma}{budget} rank={} | MSE={:.4e} rel_err={:.4e} bpp_actual={:.3}{lambda}",
                outcome.result.method,
                outcome.result.rank,
                outcome.result.mse,
                outcome.result.rel_err,
                outcome.result.bpp,
            );
        }
        stages.accumulate(&outcome.result.report);
        packed_bytes += outcome.layer.storage_bytes();
        if let Some(w) = writer.as_mut() {
            w.append(&outcome.result.method, &outcome.layer)?;
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "compressed {layers} layer(s) of {size}x{size} on {jobs_n} job(s) in {wall:.2}s ({:.2} layers/s) | serving-form weights {packed_bytes} bytes",
        layers as f64 / wall.max(1e-9),
    );
    if matches!(method, MethodSpec::LittleBit2(_)) {
        println!(
            "stage wall-clock (summed over layers): svd {:.0} ms | itq {:.0} ms | svid {:.0} ms | pack {:.0} ms",
            stages.svd_ms, stages.itq_ms, stages.svid_ms, stages.pack_ms,
        );
    }

    if let Some(w) = writer {
        w.finish()?;
        let out = args.flags.get("out").expect("writer implies --out");
        let file_bytes = std::fs::metadata(out)
            .with_context(|| format!("stat {out}"))?
            .len();
        let params = (layers * size * size) as f64;
        // The delta over packed_bytes is mostly f32-on-disk scales vs
        // their logical f16 accounting, plus O(sections) framing — see
        // EXPERIMENTS.md §Artifact.
        println!(
            "wrote {out}{}: {file_bytes} bytes ({:.3} bits/param on disk; framing + f32-scale slack {} bytes)",
            if aligned { " (v3 aligned, mmap-servable)" } else { "" },
            file_bytes as f64 * 8.0 / params,
            file_bytes as i64 - packed_bytes as i64,
        );
    }
    Ok(())
}

/// Serve a `.lb2` artifact on the dynamic-batching worker pool: load
/// once, dispatch on each layer's METHOD tag (any registered method, or a
/// mix per layer), drive `--requests` synthetic token-steps through the
/// full batched pipeline, report throughput and latency percentiles. The
/// in-process load generator stands in for a network front end — the
/// serving loop itself is the production path.
fn cmd_serve(args: &Args) -> Result<()> {
    args.known(&[
        "model",
        "workers",
        "batch",
        "threads",
        "requests",
        "listen",
        "serve-secs",
        "deadline-ms",
        "max-wait-ms",
        "chaos-seed",
        "mmap",
    ])?;
    let model_path = args
        .flags
        .get("model")
        .context("serve requires --model <file.lb2> (write one with `compress --out`)")?;
    let workers = args.get_usize("workers", 2)?;
    let batch = args.get_usize("batch", 32)?;
    let threads = args.get_usize("threads", 1)?;
    let requests = args.get_usize("requests", 256)?;
    let max_wait_ms = args.get_usize("max-wait-ms", 2)?;
    // --mmap 1: map the artifact instead of reading it; a v3 aligned file
    // serves its bit-planes straight from the page cache (every worker
    // shares the one mapping), anything else falls back to copied storage.
    let use_mmap = matches!(args.get("mmap", "0").as_str(), "1" | "true");
    if workers == 0 || batch == 0 || threads == 0 {
        bail!("--workers, --batch, and --threads must be at least 1");
    }

    let stack = Arc::new(if use_mmap {
        MethodStack::load_mmap(model_path)?
    } else {
        MethodStack::load(model_path)?
    });
    println!(
        "loaded {model_path}: method {} | depth {} | {} -> {} features | serving-form weights {} bytes",
        stack.method_summary(),
        stack.depth(),
        stack.d_in(),
        stack.d_out(),
        stack.storage_bytes()
    );
    let model_resident_bytes = stack.resident_bytes() as u64;
    let model_mapped_bytes = stack.mapped_bytes() as u64;
    if use_mmap {
        println!(
            "zero-copy load: {model_mapped_bytes} bytes borrowed from the page cache, {model_resident_bytes} bytes resident on the heap{}",
            if model_mapped_bytes == 0 { " (artifact not v3-aligned: copied)" } else { "" }
        );
    }

    // --chaos-seed: deterministic fault injection on both the wire and the
    // backend (the `make chaos` harness flips this on; production never
    // constructs the wrappers). Only meaningful for the TCP front-end.
    let chaos_seed = args.flags.get("chaos-seed").map(|s| {
        s.parse::<u64>()
            .with_context(|| format!("--chaos-seed must be a u64, got {s:?}"))
    });
    let chaos_seed = match chaos_seed {
        Some(r) => Some(r?),
        None => None,
    };
    if chaos_seed.is_some() && !args.flags.contains_key("listen") {
        bail!("--chaos-seed requires --listen (faults inject at the wire and worker boundaries)");
    }

    // --listen: the TCP front-end replaces the in-process load generator;
    // requests arrive over the wire and batch across connections.
    if let Some(listen) = args.flags.get("listen") {
        let serve_secs = args.get_usize("serve-secs", 0)?;
        let deadline_ms = args.get_usize("deadline-ms", 0)?;
        let plan = chaos_seed.map(|seed| Arc::new(FaultPlan::new(seed, FaultSpec::moderate())));
        if let Some(p) = &plan {
            println!("chaos mode: injecting faults from seed {:#x}", p.seed());
        }
        let cfg = ServingConfig {
            expect_width: Some(stack.d_in()),
            default_deadline: if deadline_ms > 0 {
                Some(Duration::from_millis(deadline_ms as u64))
            } else {
                None
            },
            batch: littlebit2::coordinator::ServerConfig {
                max_batch: batch,
                max_wait: Duration::from_millis(max_wait_ms as u64),
                queue_depth: 1024,
                workers,
                ..Default::default()
            },
            faults: plan.clone(),
            model_resident_bytes,
            model_mapped_bytes,
            ..Default::default()
        };
        let front = TcpFrontend::start(listen.as_str(), cfg, move |worker| {
            let inner = MethodStackBackend::new(Arc::clone(&stack), threads);
            match &plan {
                Some(p) => Box::new(ChaosBackend::new(inner, p.backend_injector(worker as u64)))
                    as Box<dyn littlebit2::coordinator::BatchBackend>,
                None => Box::new(inner),
            }
        })?;
        println!("listening on {} (shutdown: SHUTDOWN frame{})", front.local_addr(),
            if serve_secs > 0 { format!(" or after {serve_secs}s") } else { String::new() });
        let t0 = std::time::Instant::now();
        while !front.is_shutting_down() {
            if serve_secs > 0 && t0.elapsed() >= Duration::from_secs(serve_secs as u64) {
                front.trigger_shutdown();
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        let stats = front.shutdown();
        println!(
            "shutdown after {:.1}s: served {} | batches {} (mean size {:.1}) | rejected {} | deadline missed {} | failed {}",
            t0.elapsed().as_secs_f64(),
            stats.served,
            stats.batches,
            stats.mean_batch,
            stats.rejected,
            stats.deadline_missed,
            stats.failed
        );
        print!("{}", stats.render_metrics());
        return Ok(());
    }

    let server = InferenceServer::start_pool(
        ServerConfig {
            max_batch: batch,
            max_wait: Duration::from_millis(max_wait_ms as u64),
            queue_depth: 1024,
            workers,
            ..Default::default()
        },
        |_worker| MethodStackBackend::new(Arc::clone(&stack), threads),
    );

    let d_in = stack.d_in();
    let mut rng = Pcg64::seed(1);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|i| {
            let mut x = vec![0.0f32; d_in];
            rng.fill_normal(&mut x);
            server.submit(i as u64, x)
        })
        .collect();
    // A failed request (backend panic → dropped reply) must not abort the
    // run: collect everything, report the full stats, then exit nonzero if
    // anything failed.
    let failed = rxs.into_iter().filter(|rx| rx.recv().is_err()).count();
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    println!(
        "served {} requests on {workers} worker(s) in {wall:.3}s: {:.0} tok/s | batches {} (mean size {:.1}, mean kernel rate {:.0} tok/s) | p50 {:.2} ms p99 {:.2} ms | failed {}",
        stats.served,
        stats.tokens_per_s,
        stats.batches,
        stats.mean_batch,
        stats.mean_batch_tokens_per_s,
        stats.p50_ms,
        stats.p99_ms,
        stats.failed
    );
    if failed > 0 {
        bail!("{failed} of {requests} requests failed");
    }
    Ok(())
}

/// Wire-protocol load client for a `serve --listen` front-end:
/// `--concurrency` connections each pipeline `--requests / --concurrency`
/// INFER frames and match RESULT frames back by id. `--verify 1` replays
/// every input sequentially afterwards and asserts the replies are
/// bit-identical to the pipelined pass (the batching-invariance check,
/// end to end over real sockets). `--stats 1` prints the server metrics,
/// `--shutdown 1` asks the server to drain and exit.
fn cmd_client(args: &Args) -> Result<()> {
    args.known(&[
        "connect",
        "requests",
        "concurrency",
        "width",
        "deadline-ms",
        "verify",
        "stats",
        "shutdown",
        "retries",
        "backoff-ms",
        "budget-ms",
    ])?;
    let connect = args
        .flags
        .get("connect")
        .context("client requires --connect HOST:PORT")?
        .clone();
    let requests = args.get_usize("requests", 64)?;
    let concurrency = args.get_usize("concurrency", 4)?;
    let width = args.get_usize("width", 0)?;
    let deadline_ms = args.get_usize("deadline-ms", 0)? as u32;
    let verify = matches!(args.get("verify", "0").as_str(), "1" | "true");
    let want_stats = matches!(args.get("stats", "0").as_str(), "1" | "true");
    let want_shutdown = matches!(args.get("shutdown", "0").as_str(), "1" | "true");
    // --retries 0 (the default) keeps the plain fail-fast client; N > 0
    // switches to RetryingClient with N rounds per batch of requests.
    let retries = args.get_usize("retries", 0)?;
    let backoff_ms = args.get_usize("backoff-ms", 10)? as u64;
    let budget_ms = args.get_usize("budget-ms", 0)? as u64;
    if width == 0 {
        bail!("client requires --width <model d_in>");
    }
    if concurrency == 0 || requests == 0 {
        bail!("--requests and --concurrency must be at least 1");
    }

    let t0 = std::time::Instant::now();
    let mut threads = Vec::new();
    for c in 0..concurrency {
        // Spread the remainder so every request is issued.
        let n = requests / concurrency + usize::from(c < requests % concurrency);
        let connect = connect.clone();
        threads.push(std::thread::spawn(move || -> Result<usize> {
            if n == 0 {
                return Ok(0);
            }
            let mut rng = Pcg64::seed(derive_seed(4242, c as u64));
            let id = |r: usize| (c * 1_000_000 + r) as u64;
            let mut inputs = Vec::with_capacity(n);
            for _ in 0..n {
                let mut x = vec![0.0f32; width];
                rng.fill_normal(&mut x);
                inputs.push(x);
            }

            // Retrying mode: the self-healing client owns pipelining,
            // reconnects, and BUSY backoff; verify replays one-at-a-time
            // through the same client (retries must not change the bits).
            if retries > 0 {
                let policy = RetryPolicy {
                    max_attempts: retries,
                    base_backoff: Duration::from_millis(backoff_ms),
                    budget: (budget_ms > 0).then(|| Duration::from_millis(budget_ms)),
                    jitter_seed: derive_seed(0x7E7A, c as u64),
                    ..Default::default()
                };
                let mut client = RetryingClient::connect(connect.clone(), policy);
                let reqs: Vec<(u64, Vec<f32>)> = inputs
                    .iter()
                    .enumerate()
                    .map(|(r, x)| (id(r), x.clone()))
                    .collect();
                let got = client.infer_many(&reqs, deadline_ms)?;
                if verify {
                    for (r, x) in inputs.iter().enumerate() {
                        let again = client.infer(id(r) + 500_000, x, deadline_ms)?;
                        if again.len() != got[r].len()
                            || again
                                .iter()
                                .zip(&got[r])
                                .any(|(a, b)| a.to_bits() != b.to_bits())
                        {
                            bail!("connection {c} request {r}: replay differs from pipelined reply");
                        }
                    }
                }
                if client.retried > 0 || client.reconnects > 0 {
                    eprintln!(
                        "connection {c}: {} request-retries, {} reconnects",
                        client.retried, client.reconnects
                    );
                }
                return Ok(n);
            }

            let mut client = WireClient::connect(connect.as_str())?;
            // Pipelined pass: all sends first, then collect by id — this
            // is what lets the server coalesce cross-connection batches.
            for (r, x) in inputs.iter().enumerate() {
                client.send_infer(id(r), x, deadline_ms)?;
            }
            let mut got: std::collections::HashMap<u64, Vec<f32>> = std::collections::HashMap::new();
            for _ in 0..n {
                let f = client.recv()?;
                match f.kind {
                    FrameKind::Result => {
                        got.insert(f.id, payload_f32(&f.payload)?);
                    }
                    other => bail!("connection {c}: unexpected {other:?} frame for id {}", f.id),
                }
            }
            if verify {
                // Sequential replay: same inputs, one at a time (different
                // batch shapes server-side) — replies must not change.
                for (r, x) in inputs.iter().enumerate() {
                    let again = client.infer(id(r) + 500_000, x, deadline_ms)?;
                    let first = got
                        .get(&id(r))
                        .with_context(|| format!("connection {c}: no reply for id {}", id(r)))?;
                    if again.len() != first.len()
                        || again
                            .iter()
                            .zip(first)
                            .any(|(a, b)| a.to_bits() != b.to_bits())
                    {
                        bail!("connection {c} request {r}: replay differs from pipelined reply");
                    }
                }
            }
            Ok(n)
        }));
    }
    let mut served = 0usize;
    for (c, t) in threads.into_iter().enumerate() {
        served += t.join().map_err(|_| anyhow::anyhow!("client thread {c} panicked"))??;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{served} requests over {concurrency} connection(s) in {wall:.3}s ({:.0} req/s){}",
        served as f64 / wall.max(1e-9),
        if verify { " | verify: replay bit-identical" } else { "" }
    );

    if want_stats || want_shutdown {
        let mut client = WireClient::connect(connect.as_str())?;
        if want_stats {
            print!("{}", client.stats_text()?);
        }
        if want_shutdown {
            client.shutdown_server()?;
            println!("server acknowledged shutdown");
        }
    }
    Ok(())
}

/// Run the cluster tracker: read only the artifact's shape table, wait
/// for `--peers` JOINs, cut the shard plan (`--mode` pipeline layer
/// ranges or per-layer row shards), and front the cluster for ordinary
/// wire clients — `client` (including `--verify`/`--stats`/`--shutdown`)
/// works against a tracker unchanged. Exits on a SHUTDOWN frame or the
/// `--serve-secs` watchdog, printing the final `lb2_cluster_*` ledger;
/// a non-reconciling ledger (a request accepted but never settled) is a
/// hard error.
fn cmd_tracker(args: &Args) -> Result<()> {
    args.known(&[
        "model",
        "listen",
        "peers",
        "mode",
        "serve-secs",
        "heartbeat-ms",
        "attempts",
        "deadline-ms",
    ])?;
    let model = args
        .flags
        .get("model")
        .context("tracker requires --model <file.lb2> (write one with `compress --out`)")?;
    let listen = args.get("listen", "127.0.0.1:41700");
    let peers = args.get_usize("peers", 2)?;
    let mode = ShardMode::parse(&args.get("mode", "pipeline"))?;
    let serve_secs = args.get_usize("serve-secs", 0)?;
    let heartbeat_ms = args.get_usize("heartbeat-ms", 2000)?;
    let attempts = args.get_usize("attempts", 10)?;
    let deadline_ms = args.get_usize("deadline-ms", 10_000)?;
    if peers == 0 || attempts == 0 {
        bail!("--peers and --attempts must be at least 1");
    }
    let handle = Tracker::start(TrackerConfig {
        listen,
        expect_peers: peers,
        heartbeat_timeout: Duration::from_millis(heartbeat_ms as u64),
        attempts,
        default_deadline_ms: deadline_ms as u32,
        ..TrackerConfig::new(model, mode)
    })?;
    println!(
        "tracker on {} ({} mode): sharding over {peers} peer(s); shutdown: SHUTDOWN frame{}",
        handle.addr(),
        mode.label(),
        if serve_secs > 0 { format!(" or after {serve_secs}s") } else { String::new() }
    );
    let t0 = std::time::Instant::now();
    while !handle.is_shutting_down() {
        if serve_secs > 0 && t0.elapsed() >= Duration::from_secs(serve_secs as u64) {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    let summary = handle.shutdown();
    print!("{}", summary.stats_text);
    println!(
        "tracker drained after {:.1}s: accepted {} = served {} + failed {} + deadline-missed {} | reassignments {}",
        t0.elapsed().as_secs_f64(),
        summary.accepted,
        summary.served,
        summary.failed,
        summary.deadline_missed,
        summary.reassignments,
    );
    if !summary.reconciled {
        bail!("cluster ledger failed to reconcile: accepted != served + failed + deadline-missed");
    }
    Ok(())
}

/// Run a cluster peer: register with the tracker, receive a shard
/// assignment, and load ONLY that slice — a contiguous layer range in
/// pipeline mode (`MethodStack::load_range`, `--mmap 1` never pages in
/// out-of-range weights) or this shard's rows of every layer in row-shard
/// mode. Re-loads on every re-shard; exits when the tracker shuts it
/// down or the `--serve-secs` watchdog fires.
fn cmd_peer(args: &Args) -> Result<()> {
    args.known(&["model", "tracker", "listen", "mmap", "serve-secs", "heartbeat-ms"])?;
    let model = args
        .flags
        .get("model")
        .context("peer requires --model <file.lb2>")?;
    let tracker = args
        .flags
        .get("tracker")
        .context("peer requires --tracker HOST:PORT")?
        .clone();
    let listen = args.get("listen", "127.0.0.1:0");
    let use_mmap = matches!(args.get("mmap", "0").as_str(), "1" | "true");
    let serve_secs = args.get_usize("serve-secs", 0)?;
    let heartbeat_ms = args.get_usize("heartbeat-ms", 250)?;
    let handle = Peer::start(PeerConfig {
        listen,
        mmap: use_mmap,
        heartbeat_interval: Duration::from_millis(heartbeat_ms as u64),
        ..PeerConfig::new(tracker.clone(), model)
    })?;
    println!(
        "peer serving on {} (tracker {tracker}{})",
        handle.addr(),
        if use_mmap { ", mmap load" } else { "" }
    );
    let t0 = std::time::Instant::now();
    loop {
        if !handle.running() {
            handle.wait();
            println!("peer exited: tracker shutdown");
            return Ok(());
        }
        if serve_secs > 0 && t0.elapsed() >= Duration::from_secs(serve_secs as u64) {
            handle.stop();
            println!("peer exited: {serve_secs}s watchdog");
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// One `eval` measurement: a method (at one bpp where the method is
/// budgeted) swept over the whole compress → artifact → serve pipeline.
struct EvalRow {
    method: String,
    /// The requested bpp budget — `None` for fixed-rate methods, which
    /// never consume the knob (the JSON writes `null`, not a value that
    /// was silently ignored).
    bpp_requested: Option<f64>,
    bpp_declared: f64,
    bpp_disk: f64,
    frobenius_rel_err: f64,
    lambda_mean: Option<f64>,
    compress_ms: f64,
    artifact_bytes: u64,
    /// Heap bytes held by the served stack (owned storage plus any
    /// heap-fallback borrows) — disjoint from `mapped_bytes` by
    /// construction, so resident + mapped is the true working set and the
    /// bpp audit never double-counts a plane.
    resident_bytes: u64,
    /// Page-cache bytes borrowed through the v3 mmap load (0 for layers
    /// that had to fall back to copied storage).
    mapped_bytes: u64,
    serve_tokens_per_s: f64,
    serve_p50_ms: f64,
}

/// `eval` — the repo's first end-to-end reproduction of the paper's
/// baseline table shape: sweep `--methods` × `--bpp-list` over a
/// zoo-fabricated heavy-tailed FFN chain (γ per the Fig. 12 projection
/// profiles), run every method through the *real* pipeline
/// (compress → `.lb2` v3 aligned → mmap load → serve on the worker pool —
/// the zero-copy path, so every eval run exercises it), and write
/// `BENCH_methods.json` with fidelity (relative Frobenius error), bpp
/// (declared App. H accounting *and* on-disk), λ coherence (littlebit
/// latents; null for baselines), compression wall-clock, and serve
/// throughput. Fixed-rate methods (onebit/rtn/billm/arb) ignore the bpp
/// axis and appear once.
fn cmd_eval(args: &Args) -> Result<()> {
    args.known(&["size", "blocks", "methods", "bpp-list", "jobs", "requests", "out", "seed"])?;
    let size = args.get_usize("size", 128)?;
    let blocks = args.get_usize("blocks", 1)?;
    let jobs_n = args.get_usize("jobs", 2)?;
    let requests = args.get_usize("requests", 128)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let out_path = args.get("out", "BENCH_methods.json");
    let methods_csv = args.get("methods", &METHOD_NAMES.join(","));
    let bpp_csv = args.get("bpp-list", "1.0,0.55");
    if size == 0 || blocks == 0 || jobs_n == 0 || requests == 0 {
        bail!("--size, --blocks, --jobs, and --requests must be at least 1");
    }
    let methods: Vec<String> = methods_csv.split(',').map(|s| s.trim().to_string()).collect();
    let bpps: Vec<f64> = bpp_csv
        .split(',')
        .map(|s| s.trim().parse::<f64>().map_err(|e| anyhow::anyhow!("bad bpp {s:?}: {e}")))
        .collect::<Result<_>>()?;
    if bpps.iter().any(|&b| !(b > 0.0)) {
        bail!("every --bpp-list entry must be positive");
    }

    // Zoo-fabricated heavy-tailed chain: `blocks` SwiGLU FFN pairs
    // (up: d_ff×d_model, down: d_model×d_ff) at the paper's γ profile,
    // dims scaled so d_model ≈ --size. All methods compress the SAME
    // weights — the apples-to-apples requirement.
    let arch = ArchSpec::llama2_7b();
    let shrink = (arch.d_model / size).max(1);
    let weights: Vec<littlebit2::linalg::Mat> = (0..blocks)
        .flat_map(|b| zoo::fabricate_ffn_chain(&arch, shrink, derive_seed(seed, b as u64)))
        .collect();
    let params: u64 = weights.iter().map(|w| (w.rows() * w.cols()) as u64).sum();
    println!(
        "eval chain: {} layers ({} params), dims {}",
        weights.len(),
        params,
        weights
            .iter()
            .map(|w| format!("{}x{}", w.rows(), w.cols()))
            .collect::<Vec<_>>()
            .join(" → ")
    );

    let tmp_dir = std::env::temp_dir();
    let mut rows: Vec<EvalRow> = Vec::new();
    for name in &methods {
        // Budgeted methods sweep the bpp axis; fixed-rate methods run
        // once, with no requested-bpp value (the knob has no effect —
        // MethodSpec::is_budgeted is the single source of that split).
        let sweep: Vec<Option<f64>> =
            if MethodSpec::parse(name, 1.0, InitStrategy::Standard)?.is_budgeted() {
                bpps.iter().map(|&b| Some(b)).collect()
            } else {
                vec![None]
            };
        for requested in sweep {
            let bpp = requested.unwrap_or(1.0);
            let method = MethodSpec::parse(name, bpp, InitStrategy::JointItq { iters: 50 })?;
            let jobs: Vec<CompressionJob> = weights
                .iter()
                .enumerate()
                .map(|(k, w)| CompressionJob {
                    name: format!("layer{k}"),
                    input: JobInput::Dense(w.clone()),
                    method: method.clone(),
                    seed: derive_seed(seed.wrapping_add(1), k as u64),
                })
                .collect();

            let mut layers: Vec<MethodStackLayer> = Vec::with_capacity(jobs.len());
            let mut err_num = 0.0f64;
            let mut err_den = 0.0f64;
            let mut declared_bits = 0u64;
            let mut compress_ms = 0.0f64;
            let mut lambdas: Vec<f64> = Vec::new();
            run_compression_jobs_streaming(jobs, jobs_n, |_, outcome| {
                let r = &outcome.result;
                // rel_err is per-layer ‖W−Ŵ‖²/‖W‖²; re-weight by ‖W‖² to
                // aggregate over the chain exactly.
                let w = &weights[layers.len()];
                let fro = w.fro_norm().powi(2);
                err_num += r.rel_err * fro;
                err_den += fro;
                declared_bits += outcome.layer.declared_bits();
                // Compression-only wall-clock (wall_ms additionally
                // counts the reconstruction + scoring pass, which would
                // skew the cross-method timing column).
                compress_ms += r.report.total_ms;
                if let Some(l) = r.lambda_mean {
                    lambdas.push(l);
                }
                layers.push(MethodStackLayer {
                    method: r.method.clone(),
                    layer: outcome.layer,
                });
                Ok(())
            })?;
            let stack = MethodStack::try_new(layers)?;

            // Through the real artifact: save, stat, load, serve.
            let path = tmp_dir.join(format!(
                "lb2_eval_{}_{name}_{bpp}.lb2",
                std::process::id()
            ));

            stack.save_aligned(&path)?;
            // Cleanup-on-error: a failed stat/load must not strand the
            // temp artifact (same discipline as the artifact writers).
            // Unlinking after the mmap load is fine on unix — the mapping
            // keeps the pages alive until the stack is dropped.
            let reload = || -> Result<(u64, MethodStack)> {
                let bytes = std::fs::metadata(&path)
                    .with_context(|| format!("stat {path:?}"))?
                    .len();
                Ok((bytes, MethodStack::load_mmap(&path)?))
            };
            let result = reload();
            let _ = std::fs::remove_file(&path);
            let (artifact_bytes, loaded) = result?;
            let loaded = Arc::new(loaded);
            let resident_bytes = loaded.resident_bytes() as u64;
            let mapped_bytes = loaded.mapped_bytes() as u64;

            let server = InferenceServer::start_pool(
                ServerConfig {
                    max_batch: 16,
                    max_wait: Duration::from_millis(1),
                    queue_depth: 1024,
                    workers: 2,
                    ..Default::default()
                },
                |_worker| MethodStackBackend::new(Arc::clone(&loaded), 1),
            );
            let mut rng = Pcg64::seed(derive_seed(seed, 99));
            let d_in = loaded.d_in();
            let rxs: Vec<_> = (0..requests)
                .map(|i| {
                    let mut x = vec![0.0f32; d_in];
                    rng.fill_normal(&mut x);
                    server.submit(i as u64, x)
                })
                .collect();
            let failed = rxs.into_iter().filter(|rx| rx.recv().is_err()).count();
            let stats = server.shutdown();
            if failed > 0 {
                bail!("{name}: {failed} of {requests} eval requests failed");
            }

            let row = EvalRow {
                method: name.clone(),
                bpp_requested: requested,
                bpp_declared: declared_bits as f64 / params as f64,
                bpp_disk: artifact_bytes as f64 * 8.0 / params as f64,
                frobenius_rel_err: if err_den > 0.0 { err_num / err_den } else { 0.0 },
                lambda_mean: if lambdas.is_empty() {
                    None
                } else {
                    Some(lambdas.iter().sum::<f64>() / lambdas.len() as f64)
                },
                compress_ms,
                artifact_bytes,
                resident_bytes,
                mapped_bytes,
                serve_tokens_per_s: stats.tokens_per_s,
                serve_p50_ms: stats.p50_ms,
            };
            let req = row
                .bpp_requested
                .map(|b| b.to_string())
                .unwrap_or_else(|| "-".to_string());
            println!(
                "{:<11} bpp_req={:<5} bpp_decl={:>6.3} bpp_disk={:>7.3} rel_err={:.4e} compress={:>7.0} ms serve={:>8.0} tok/s mapped={} B resident={} B",
                row.method,
                req,
                row.bpp_declared,
                row.bpp_disk,
                row.frobenius_rel_err,
                row.compress_ms,
                row.serve_tokens_per_s,
                row.mapped_bytes,
                row.resident_bytes,
            );
            rows.push(row);
        }
    }

    write_eval_json(&out_path, size, blocks, requests, params, &rows)?;
    println!("wrote {out_path} ({} method rows)", rows.len());
    Ok(())
}

/// Hand-rolled JSON emitter for `BENCH_methods.json` (no serde in the
/// offline build; same style as the bench JSON writers).
fn write_eval_json(
    path: &str,
    size: usize,
    blocks: usize,
    requests: usize,
    params: u64,
    rows: &[EvalRow],
) -> Result<()> {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"bench\": \"method_matrix\",\n");
    s.push_str("  \"status\": \"ok\",\n");
    s.push_str(&format!(
        "  \"generated_by\": \"littlebit2 {} eval\",\n",
        littlebit2::VERSION
    ));
    s.push_str(&format!(
        "  \"config\": {{\"size\": {size}, \"blocks\": {blocks}, \"requests\": {requests}, \"params\": {params}}},\n"
    ));
    s.push_str("  \"methods\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let lambda = match r.lambda_mean {
            Some(l) => format!("{l:.6}"),
            None => "null".to_string(),
        };
        let requested = match r.bpp_requested {
            Some(b) => b.to_string(),
            None => "null".to_string(),
        };
        s.push_str(&format!(
            "    {{\"method\": \"{}\", \"bpp_requested\": {requested}, \"bpp_declared\": {:.6}, \"bpp_disk\": {:.6}, \"frobenius_rel_err\": {:.8e}, \"lambda_mean\": {lambda}, \"compress_ms\": {:.3}, \"artifact_bytes\": {}, \"resident_bytes\": {}, \"mapped_bytes\": {}, \"serve_tokens_per_s\": {:.1}, \"serve_p50_ms\": {:.4}}}{}\n",
            r.method,
            r.bpp_declared,
            r.bpp_disk,
            r.frobenius_rel_err,
            r.compress_ms,
            r.artifact_bytes,
            r.resident_bytes,
            r.mapped_bytes,
            r.serve_tokens_per_s,
            r.serve_p50_ms,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(path, s).with_context(|| format!("writing {path}"))?;
    Ok(())
}

/// The e2e QAKD driver needs the PJRT runtime (`xla` crate), which the
/// offline build image cannot provide — see ARCHITECTURE.md.
#[cfg(not(feature = "xla"))]
fn cmd_train(_args: &Args) -> Result<()> {
    bail!("the `train` subcommand executes AOT artifacts through PJRT; rebuild with `--features xla` (requires vendoring the xla crate, see ARCHITECTURE.md)")
}

/// The e2e QAKD driver (quick path; `examples/e2e_qat.rs` is the recorded run).
#[cfg(feature = "xla")]
fn cmd_train(args: &Args) -> Result<()> {
    args.known(&["artifacts", "teacher-steps", "student-steps", "variant", "lr"])?;
    let artifacts = args.get("artifacts", "artifacts");
    let teacher_steps = args.get_usize("teacher-steps", 100)?;
    let student_steps = args.get_usize("student-steps", 100)?;
    let lr = args.get_f64("lr", 1e-3)? as f32;
    let variant = match args.get("variant", "littlebit2").as_str() {
        "tinyrank" => StudentVariant::TinyRankFp,
        "littlebit" => StudentVariant::LittleBit,
        "rotation" => StudentVariant::RandomRotation,
        "littlebit2" => StudentVariant::LittleBit2 { itq_iters: 50 },
        other => bail!("unknown variant {other:?}"),
    };
    let driver = QatDriver::new(&artifacts, 1234)?;
    println!(
        "platform={} preset={} model d={} L={} vocab={}",
        driver.runtime().platform(),
        driver.manifest.preset,
        driver.manifest.config.d_model,
        driver.manifest.config.n_layers,
        driver.manifest.config.vocab
    );
    println!("— teacher pretraining ({teacher_steps} steps) —");
    let (teacher, t_losses) = driver.train_teacher(teacher_steps, lr, |s, l| {
        if s % 10 == 0 {
            println!("teacher step {s:>5} loss {l:.4}");
        }
    })?;
    println!("teacher final loss {:.4}", t_losses.last().unwrap());

    println!("— student QAKD: {} ({student_steps} steps) —", variant.label());
    let outcome = driver.train_student(&teacher, variant, student_steps, lr, |s, l, f| {
        if s % 10 == 0 {
            println!("student step {s:>5} loss {l:.4} flip {f:.4}");
        }
    })?;
    println!(
        "student {} eval CE {:.4} (PPL {:.2})",
        variant.label(),
        outcome.final_eval_ce,
        outcome.final_eval_ce.exp()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::Args;

    fn argv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flag_value_pairs() {
        let args = Args::parse(&argv(&["--size", "64", "--bpp", "0.8"])).unwrap();
        assert_eq!(args.get("size", "0"), "64");
        assert_eq!(args.get_f64("bpp", 0.0).unwrap(), 0.8);
        assert_eq!(args.get_usize("missing", 7).unwrap(), 7);
    }

    /// Regression: `--size --bpp 0.8` used to set `size="--bpp"` silently.
    #[test]
    fn flag_as_value_is_rejected() {
        let err = Args::parse(&argv(&["--size", "--bpp", "0.8"])).unwrap_err();
        assert!(err.to_string().contains("--size"), "{err}");
    }

    /// Regression: a repeated flag used to silently keep only the last value.
    #[test]
    fn duplicate_flag_is_rejected() {
        let err = Args::parse(&argv(&["--size", "1", "--size", "2"])).unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn trailing_flag_without_value_is_rejected() {
        assert!(Args::parse(&argv(&["--size"])).is_err());
        assert!(Args::parse(&argv(&["--size", "1", "--out"])).is_err());
    }

    #[test]
    fn bare_double_dash_and_positional_are_rejected() {
        assert!(Args::parse(&argv(&["--"])).is_err());
        assert!(Args::parse(&argv(&["stray"])).is_err());
    }

    /// Negative numbers are still fine as values (only `--`-prefixed
    /// tokens are treated as flags).
    #[test]
    fn negative_value_is_accepted() {
        let args = Args::parse(&argv(&["--gamma", "-0.3"])).unwrap();
        assert_eq!(args.get_f64("gamma", 0.0).unwrap(), -0.3);
    }

    /// A misspelled flag (`--ouy` for `--out`) must fail the subcommand,
    /// not silently run without the intended effect.
    #[test]
    fn unknown_flag_is_rejected_by_allowlist() {
        let args = Args::parse(&argv(&["--ouy", "model.lb2"])).unwrap();
        let err = args.known(&["size", "out"]).map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("--ouy"), "{err}");
        assert!(args.known(&["ouy"]).is_ok());
    }
}
