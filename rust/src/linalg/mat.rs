//! Row-major dense `f32` matrix with an aligned, padded row stride and
//! cache-blocked, pool-parallel multiplies.
//!
//! Storage follows the TinyNet `real_col` idiom: each logical row of `cols`
//! elements occupies [`stride`](Mat::stride) = `cols` rounded up to 8 floats
//! (one 32-byte AVX2 vector) in a 32-byte-aligned backing buffer
//! ([`AlignedF32`]), so every row starts on a vector boundary and SIMD lanes
//! never straddle rows. The padding tail of every row is kept **zero** as a
//! type invariant — every constructor and mutating op re-establishes it
//! (checked by [`padding_is_clear`](Mat::padding_is_clear)), which lets
//! whole-buffer reductions (`fro_norm`, `l1_norm`) run over the padded
//! backing with bit-identical results. The logical API (`row`/`at`/`col`)
//! is unchanged; flat iteration over `rows*cols` contiguous data is gone —
//! use [`to_vec`](Mat::to_vec) for a logical copy or
//! [`padded`](Mat::padded) + [`stride`](Mat::stride) for the raw layout.
//!
//! The product kernels come in two forms: the classic serial entry points
//! (`matmul`, `t_matmul`, `matmul_t`, `matvec`) and `_on` variants taking
//! a [`Pool`] that partition **output rows** into contiguous ranges across
//! the pool's threads. Each output element keeps a fixed k-order
//! accumulation — a range job computes exactly what the serial kernel
//! would compute for those rows — so pooled results are **bit-identical**
//! to serial for any thread count (asserted by `tests/parallel_linalg.rs`
//! across thread counts {1, 2, 7, 64}). The saxpy inner loop dispatches to
//! the AVX2 lane of [`crate::packing::simd`] when available — element-wise,
//! no reduction-order change, so SIMD stays bit-identical too. Shapes below
//! [`PAR_MIN_FLOPS`] stay inline on the caller: dispatch overhead would
//! dominate, and the threshold depends only on the shape, never on pool
//! occupancy.

use super::aligned::{AlignedF32, F32_BLOCK};
use crate::packing::simd;
use crate::parallel::Pool;
use crate::rng::Pcg64;
use std::fmt;

/// Padded row stride (in `f32`s) for a logical width of `cols`.
#[inline]
pub(crate) fn row_stride(cols: usize) -> usize {
    cols.div_ceil(F32_BLOCK) * F32_BLOCK
}

/// Dense row-major matrix with an 8-float padded row stride.
#[derive(Clone)]
pub struct Mat {
    rows: usize,
    cols: usize,
    /// Allocated row width; `cols.div_ceil(8) * 8`.
    stride: usize,
    /// `rows * stride` elements, 32-byte aligned; per-row tail past `cols`
    /// is always zero.
    data: AlignedF32,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl PartialEq for Mat {
    /// Logical equality: shape plus per-row element comparison (IEEE `f32`
    /// semantics). Padding never participates, so two equal matrices with
    /// different padding histories still compare equal.
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && (0..self.rows).all(|i| self.row(i) == other.row(i))
    }
}

/// Blocking factor for the matmul micro-kernel. 64×64 f32 tiles (16 KiB)
/// comfortably fit L1 alongside the accumulator.
const BLOCK: usize = 64;

/// Minimum multiply count (`m·k·n`) before a product is worth splitting
/// across the pool — below this, channel dispatch costs more than the
/// arithmetic. Shape-only, so the serial/parallel decision is
/// deterministic (and bit-irrelevant either way).
const PAR_MIN_FLOPS: usize = 128 * 1024;

impl Default for Mat {
    /// Empty 0×0 matrix — the placeholder state of reusable scratch buffers
    /// (see `packing::BatchScratch`), grown in place by [`Mat::resize`].
    fn default() -> Self {
        Self::zeros(0, 0)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let stride = row_stride(cols);
        Self { rows, cols, stride, data: AlignedF32::zeros(rows * stride) }
    }

    /// Reshape in place, reusing the existing allocation where possible
    /// (only grows when the padded size exceeds every earlier size). A
    /// same-shape call is a no-op that keeps the contents; any shape change
    /// clears the whole buffer to zero — the stride may change, so flat
    /// carry-over of old values would be meaningless, and clearing
    /// re-establishes the padding invariant in one pass. The batched
    /// serving scratch uses this to stay allocation-free across requests
    /// of varying batch size (those kernels fully overwrite their logical
    /// outputs anyway).
    pub fn resize(&mut self, rows: usize, cols: usize) {
        if rows == self.rows && cols == self.cols {
            return;
        }
        self.rows = rows;
        self.cols = cols;
        self.stride = row_stride(cols);
        self.data.resize(rows * self.stride);
        self.data.as_mut_slice().fill(0.0);
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            m.row_mut(i).copy_from_slice(&data[i * cols..(i + 1) * cols]);
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            let row = m.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v = f(i, j);
            }
        }
        m
    }

    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// i.i.d. standard normal entries.
    pub fn gaussian(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let mut m = Self::zeros(rows, cols);
        m.fill_normal(rng);
        m
    }

    /// Fill every logical element with i.i.d. standard normals, row by row.
    /// Draws exactly `rows*cols` variates in row-major order — the same
    /// stream a flat fill of the old contiguous layout consumed, so seeded
    /// expectations are layout-independent.
    pub fn fill_normal(&mut self, rng: &mut Pcg64) {
        for i in 0..self.rows {
            rng.fill_normal(self.row_mut(i));
        }
    }

    /// Fill every logical element with i.i.d. uniforms on `[lo, hi)`,
    /// row-major order (see [`fill_normal`](Self::fill_normal)).
    pub fn fill_uniform(&mut self, rng: &mut Pcg64, lo: f32, hi: f32) {
        for i in 0..self.rows {
            rng.fill_uniform(self.row_mut(i), lo, hi);
        }
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f32]) -> Self {
        let n = d.len();
        Self::from_fn(n, n, |i, j| if i == j { d[i] } else { 0.0 })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Allocated row width in `f32`s — `cols` rounded up to a multiple of 8.
    #[inline]
    pub fn stride(&self) -> usize {
        self.stride
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data.as_slice()[i * self.stride + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data.as_mut_slice()[i * self.stride + j]
    }

    /// Logical row `i` — `cols` elements, excluding padding.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data.as_slice()[i * self.stride..i * self.stride + self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let (s, c) = (self.stride, self.cols);
        &mut self.data.as_mut_slice()[i * s..i * s + c]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    /// The full padded backing buffer (`rows * stride` elements, 32-byte
    /// aligned). Row `i` starts at `i * stride`; elements past `cols` in
    /// each row are zero by invariant. Read-only — writers go through
    /// [`padded_mut`](Self::padded_mut) inside the crate so the padding
    /// invariant stays enforceable.
    #[inline]
    pub fn padded(&self) -> &[f32] {
        self.data.as_slice()
    }

    /// Mutable padded backing, for the stride-aware kernels. Callers must
    /// leave the per-row tail past `cols` zero.
    #[inline]
    pub(crate) fn padded_mut(&mut self) -> &mut [f32] {
        self.data.as_mut_slice()
    }

    /// Copy out the logical `rows*cols` elements, row-major and contiguous
    /// (the pre-padding memory layout) — the bridge to APIs that want a
    /// flat buffer.
    pub fn to_vec(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for i in 0..self.rows {
            out.extend_from_slice(self.row(i));
        }
        out
    }

    /// True when every per-row padding tail is exactly `+0.0` — the layout
    /// invariant all mutating ops preserve and the SIMD kernels rely on.
    pub fn padding_is_clear(&self) -> bool {
        let d = self.data.as_slice();
        (0..self.rows).all(|i| {
            d[i * self.stride + self.cols..(i + 1) * self.stride]
                .iter()
                .all(|v| v.to_bits() == 0)
        })
    }

    pub fn transpose(&self) -> Mat {
        // Blocked transpose to stay cache-friendly on the 4096² inputs.
        let mut t = Mat::zeros(self.cols, self.rows);
        let ts = t.stride;
        let td = t.data.as_mut_slice();
        for bi in (0..self.rows).step_by(BLOCK) {
            for bj in (0..self.cols).step_by(BLOCK) {
                let ie = (bi + BLOCK).min(self.rows);
                let je = (bj + BLOCK).min(self.cols);
                for i in bi..ie {
                    let row = self.row(i);
                    for j in bj..je {
                        td[j * ts + i] = row[j];
                    }
                }
            }
        }
        t
    }

    /// `self @ other` — cache-blocked i-k-j loop with the k-panel of `other`
    /// streaming through L1/L2. Serial entry; [`matmul_on`](Self::matmul_on)
    /// is the pool-parallel twin (bit-identical output).
    pub fn matmul(&self, other: &Mat) -> Mat {
        self.matmul_on(other, Pool::serial())
    }

    /// `self @ other` with output rows partitioned across `pool`. Each row
    /// range runs the exact serial blocked kernel (fixed k-order per output
    /// element), so the result is bit-identical to [`matmul`](Self::matmul)
    /// for any thread count. Products under [`PAR_MIN_FLOPS`] stay inline.
    pub fn matmul_on(&self, other: &Mat, pool: &Pool) -> Mat {
        let mut out = Mat::default();
        self.matmul_into_on(other, &mut out, pool);
        out
    }

    /// [`matmul_on`](Self::matmul_on) into a caller-owned output buffer:
    /// `out` is resized to `m × n` in place (reusing its allocation), so a
    /// steady-state serving loop performs zero heap allocations — the
    /// dense-variant twin of the packed `forward_batch_into` contract.
    /// Bit-identical to [`matmul`](Self::matmul).
    pub fn matmul_into_on(&self, other: &Mat, out: &mut Mat, pool: &Pool) {
        self.matmul_into_parts_on(other, out, pool, pool.threads())
    }

    /// [`matmul_into_on`](Self::matmul_into_on) with an explicit row-range
    /// partition count (≤ pool width is typical): the serving path's
    /// per-worker `threads` knob, matching the sign kernels' contract —
    /// the partition never changes a bit, only the parallelism budget.
    pub fn matmul_into_parts_on(&self, other: &Mat, out: &mut Mat, pool: &Pool, parts: usize) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch {:?} @ {:?}", self, other);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        out.resize(m, n);
        // The blocked kernel accumulates; clear whatever the reused buffer
        // last held (padding included — one pass keeps the invariant).
        out.data.as_mut_slice().fill(0.0);
        if m == 0 || n == 0 {
            return;
        }
        let os = out.stride;
        let parts = if m * k * n < PAR_MIN_FLOPS { 1 } else { parts.max(1) };
        pool.run_row_chunks(out.data.as_mut_slice(), os, parts, |row0, orows| {
            let nrows = orows.len() / os;
            for bk in (0..k).step_by(BLOCK) {
                let ke = (bk + BLOCK).min(k);
                for di in 0..nrows {
                    let arow = self.row(row0 + di);
                    let orow = &mut orows[di * os..di * os + n];
                    for p in bk..ke {
                        let a = arow[p];
                        if a == 0.0 {
                            continue;
                        }
                        // Inner j-loop is a saxpy: element-wise, so the
                        // AVX2 lane is bit-identical to scalar.
                        simd::axpy(a, other.row(p), orow);
                    }
                }
            }
        });
    }

    /// `selfᵀ @ other` without materializing the transpose. Serial entry;
    /// [`t_matmul_on`](Self::t_matmul_on) is the pool-parallel twin.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        self.t_matmul_on(other, Pool::serial())
    }

    /// `selfᵀ @ other` with output rows partitioned across `pool` —
    /// bit-identical to [`t_matmul`](Self::t_matmul) for any thread count
    /// (k ascends identically per output element in every range).
    pub fn t_matmul_on(&self, other: &Mat, pool: &Pool) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        if m == 0 || n == 0 {
            return out;
        }
        let os = out.stride;
        let parts = if m * k * n < PAR_MIN_FLOPS { 1 } else { pool.threads() };
        pool.run_row_chunks(out.data.as_mut_slice(), os, parts, |row0, orows| {
            let nrows = orows.len() / os;
            for p in 0..k {
                let arow = self.row(p);
                let brow = other.row(p);
                for di in 0..nrows {
                    let a = arow[row0 + di];
                    if a == 0.0 {
                        continue;
                    }
                    let orow = &mut orows[di * os..di * os + n];
                    simd::axpy(a, brow, orow);
                }
            }
        });
        out
    }

    /// `self @ otherᵀ` without materializing the transpose. Serial entry;
    /// [`matmul_t_on`](Self::matmul_t_on) is the pool-parallel twin.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        self.matmul_t_on(other, Pool::serial())
    }

    /// `self @ otherᵀ` with output rows partitioned across `pool` —
    /// bit-identical to [`matmul_t`](Self::matmul_t) for any thread count
    /// (each element is one fixed-order f64 dot).
    pub fn matmul_t_on(&self, other: &Mat, pool: &Pool) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        if m == 0 || n == 0 {
            return out;
        }
        let os = out.stride;
        let parts = if m * k * n < PAR_MIN_FLOPS { 1 } else { pool.threads() };
        pool.run_row_chunks(out.data.as_mut_slice(), os, parts, |row0, orows| {
            for (di, orow) in orows.chunks_mut(os).enumerate() {
                let arow = self.row(row0 + di);
                for (j, o) in orow[..n].iter_mut().enumerate() {
                    *o = super::dot(arow, other.row(j)) as f32;
                }
            }
        });
        out
    }

    /// Matrix-vector product `self @ x`. Serial entry;
    /// [`matvec_on`](Self::matvec_on) is the pool-parallel twin.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        self.matvec_on(x, Pool::serial())
    }

    /// `self @ x` with output rows partitioned across `pool` —
    /// bit-identical to [`matvec`](Self::matvec) for any thread count.
    pub fn matvec_on(&self, x: &[f32], pool: &Pool) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        let mut out = vec![0.0f32; self.rows];
        let parts = if self.rows * self.cols < PAR_MIN_FLOPS { 1 } else { pool.threads() };
        pool.run_row_chunks(&mut out, 1, parts, |row0, orows| {
            for (di, o) in orows.iter_mut().enumerate() {
                *o = super::dot(self.row(row0 + di), x) as f32;
            }
        });
        out
    }

    /// Scale row `i` by `s[i]` — `diag(s) @ self`. Per-row so padding never
    /// sees `s` (a non-finite scale must not contaminate the zero tail).
    pub fn scale_rows(&self, s: &[f32]) -> Mat {
        assert_eq!(s.len(), self.rows);
        let mut out = self.clone();
        for i in 0..self.rows {
            let si = s[i];
            for v in out.row_mut(i) {
                *v *= si;
            }
        }
        out
    }

    /// Scale column `j` by `s[j]` — `self @ diag(s)`.
    pub fn scale_cols(&self, s: &[f32]) -> Mat {
        assert_eq!(s.len(), self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            for (v, &sj) in out.row_mut(i).iter_mut().zip(s) {
                *v *= sj;
            }
        }
        out
    }

    /// Element-wise map over logical elements; padding stays untouched
    /// (zero). The shared body of the unary ops below.
    fn map_rows(&self, mut f: impl FnMut(f32) -> f32) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for (o, &v) in out.row_mut(i).iter_mut().zip(self.row(i)) {
                *o = f(v);
            }
        }
        out
    }

    /// Element-wise zip with `other` (same shape ⇒ same stride).
    fn zip_rows(&self, other: &Mat, mut f: impl FnMut(f32, f32) -> f32) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (a, b) = (self.row(i), other.row(i));
            for (j, o) in out.row_mut(i).iter_mut().enumerate() {
                *o = f(a[j], b[j]);
            }
        }
        out
    }

    pub fn add(&self, other: &Mat) -> Mat {
        self.zip_rows(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        self.zip_rows(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f32) -> Mat {
        self.map_rows(|a| a * s)
    }

    /// Element-wise absolute value.
    pub fn abs(&self) -> Mat {
        self.map_rows(|a| a.abs())
    }

    /// Element-wise sign in {−1, +1} (zero maps to +1, matching
    /// `torch.sign`-with-STE conventions used by the paper's Listing 2 where
    /// exact zeros are measure-zero). Logical elements only — the 0 → +1
    /// mapping must never touch the zero padding tail.
    pub fn signum(&self) -> Mat {
        self.map_rows(|a| if a < 0.0 { -1.0 } else { 1.0 })
    }

    /// Frobenius norm (f64 accumulation). Runs over the padded backing:
    /// the zero tail contributes exact `+0.0` terms, so the fold is
    /// bit-identical to the logical-only reduction.
    pub fn fro_norm(&self) -> f64 {
        super::dot(self.data.as_slice(), self.data.as_slice()).sqrt()
    }

    /// L1 norm over logical elements (f64 accumulation; padded fold — the
    /// zero tail is a no-op, as in [`fro_norm`](Self::fro_norm)).
    pub fn l1_norm(&self) -> f64 {
        super::norm1(self.data.as_slice())
    }

    /// Squared Frobenius distance ‖self − other‖²_F.
    pub fn fro_dist2(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        // Same shape ⇒ same stride; paddings are both zero, so the padded
        // zip adds exact zeros and matches the logical fold bit for bit.
        let mut acc = 0.0f64;
        for (a, b) in self.data.as_slice().iter().zip(other.data.as_slice()) {
            let d = (*a - *b) as f64;
            acc += d * d;
        }
        acc
    }

    /// Mean squared error against `other`.
    pub fn mse(&self, other: &Mat) -> f64 {
        self.fro_dist2(other) / (self.rows * self.cols) as f64
    }

    /// Take the first `r` columns.
    pub fn take_cols(&self, r: usize) -> Mat {
        assert!(r <= self.cols);
        let mut out = Mat::zeros(self.rows, r);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..r]);
        }
        out
    }

    /// Vertical concatenation `[self; other]`.
    pub fn vcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut out = Mat::zeros(self.rows + other.rows, self.cols);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(self.row(i));
        }
        for i in 0..other.rows {
            out.row_mut(self.rows + i).copy_from_slice(other.row(i));
        }
        out
    }

    /// Split vertically after `k` rows.
    pub fn vsplit(&self, k: usize) -> (Mat, Mat) {
        assert!(k <= self.rows);
        let mut top = Mat::zeros(k, self.cols);
        for i in 0..k {
            top.row_mut(i).copy_from_slice(self.row(i));
        }
        let mut bottom = Mat::zeros(self.rows - k, self.cols);
        for i in k..self.rows {
            bottom.row_mut(i - k).copy_from_slice(self.row(i));
        }
        (top, bottom)
    }

    /// Round-trip through IEEE half precision, modelling FP16 storage of
    /// scales/weights in the memory-budget comparisons.
    pub fn to_f16_precision(&self) -> Mat {
        self.map_rows(f16_round)
    }
}

/// Round an f32 to the nearest representable IEEE binary16 value
/// (round-to-nearest-even), returned as f32.
pub fn f16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let sign = bits & 0x8000_0000;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN pass through.
        return x;
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        // Overflow to ±inf in f16.
        return f32::from_bits(sign | 0x7f80_0000);
    }
    if unbiased < -24 {
        return f32::from_bits(sign); // underflow to ±0
    }
    if unbiased < -14 {
        // Subnormal in f16: quantize the significand to the coarser grid.
        let shift = (-14 - unbiased) as u32; // 1..=10
        let q = 13 + shift; // bits of the f32 fraction to drop
        let full = frac | 0x0080_0000; // implicit leading 1
        let half = 1u32 << (q - 1);
        let rounded = round_half_even(full, q, half);
        let val = (rounded as f64) * 2f64.powi(unbiased - 23 + q as i32);
        let out = if sign != 0 { -val } else { val };
        return out as f32;
    }
    // Normal: keep 10 fraction bits, round-half-even on the lower 13.
    let half = 1u32 << 12;
    let rounded_frac = round_half_even(frac, 13, half);
    if rounded_frac >= 0x0080_0000 >> 13 << 13 {} // no-op; clarity
    let mut new_exp = exp;
    let mut new_frac = rounded_frac << 13;
    if new_frac > 0x007f_ffff {
        new_frac = 0;
        new_exp += 1;
        if new_exp - 127 > 15 {
            return f32::from_bits(sign | 0x7f80_0000);
        }
    }
    f32::from_bits(sign | ((new_exp as u32) << 23) | new_frac)
}

#[inline]
fn round_half_even(v: u32, drop_bits: u32, half: u32) -> u32 {
    let kept = v >> drop_bits;
    let rem = v & ((1 << drop_bits) - 1);
    if rem > half || (rem == half && kept & 1 == 1) {
        kept + 1
    } else {
        kept
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_matches_naive() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.to_vec(), vec![58., 64., 139., 154.]);
    }

    /// The into-buffer form must be bit-identical to `matmul` while
    /// reusing one output across differently-shaped (and stale-valued)
    /// calls — the dense serving-path contract.
    #[test]
    fn matmul_into_on_reuses_buffer_cleanly() {
        let mut rng = Pcg64::seed(91);
        let mut out = Mat::zeros(40, 40);
        out.fill_normal(&mut rng); // stale garbage to overwrite
        for (m, k, n) in [(7usize, 9usize, 5usize), (3, 2, 8), (12, 4, 1)] {
            let a = Mat::gaussian(m, k, &mut rng);
            let b = Mat::gaussian(k, n, &mut rng);
            a.matmul_into_on(&b, &mut out, Pool::serial());
            assert_eq!(out, a.matmul(&b), "{m}x{k}x{n}");
            assert!(out.padding_is_clear(), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_t_and_t_matmul_agree_with_explicit_transpose() {
        let mut rng = Pcg64::seed(4);
        let a = Mat::gaussian(17, 9, &mut rng);
        let b = Mat::gaussian(17, 5, &mut rng);
        let c1 = a.t_matmul(&b);
        let c2 = a.transpose().matmul(&b);
        assert!(c1.fro_dist2(&c2) < 1e-6);

        let d = Mat::gaussian(5, 9, &mut rng);
        let e1 = a.matmul_t(&d); // 17x5
        let e2 = a.matmul(&d.transpose());
        assert!(e1.fro_dist2(&e2) < 1e-6);
    }

    #[test]
    fn blocked_matmul_large_shape() {
        let mut rng = Pcg64::seed(8);
        let a = Mat::gaussian(130, 70, &mut rng);
        let b = Mat::gaussian(70, 90, &mut rng);
        let c = a.matmul(&b);
        // Spot check a few entries against dot products.
        for &(i, j) in &[(0, 0), (129, 89), (65, 45)] {
            let expect = crate::linalg::dot(a.row(i), &b.col(j)) as f32;
            assert!((c.at(i, j) - expect).abs() < 1e-3);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seed(2);
        let a = Mat::gaussian(33, 65, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn scale_rows_cols() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let r = a.scale_rows(&[2., 3.]);
        assert_eq!(r.to_vec(), vec![2., 4., 9., 12.]);
        let c = a.scale_cols(&[2., 3.]);
        assert_eq!(c.to_vec(), vec![2., 6., 6., 12.]);
    }

    #[test]
    fn vcat_vsplit_roundtrip() {
        let mut rng = Pcg64::seed(3);
        let a = Mat::gaussian(7, 4, &mut rng);
        let b = Mat::gaussian(5, 4, &mut rng);
        let z = a.vcat(&b);
        let (a2, b2) = z.vsplit(7);
        assert_eq!(a, a2);
        assert_eq!(b, b2);
    }

    #[test]
    fn signum_maps_zero_to_plus_one() {
        let a = Mat::from_vec(1, 3, vec![-0.5, 0.0, 0.5]);
        assert_eq!(a.signum().to_vec(), vec![-1., 1., 1.]);
    }

    #[test]
    fn f16_round_exact_values() {
        assert_eq!(f16_round(1.0), 1.0);
        assert_eq!(f16_round(0.5), 0.5);
        assert_eq!(f16_round(-2.0), -2.0);
        assert_eq!(f16_round(0.0), 0.0);
        // 1 + 2^-11 rounds to 1.0 in f16 (10 fraction bits, half-even).
        assert_eq!(f16_round(1.0 + 2f32.powi(-11)), 1.0);
        // 1 + 2^-10 is representable.
        assert_eq!(f16_round(1.0 + 2f32.powi(-10)), 1.0 + 2f32.powi(-10));
        // Overflow behaviour.
        assert!(f16_round(1e6).is_infinite());
        // Subnormal: 2^-25 underflows to zero.
        assert_eq!(f16_round(2f32.powi(-25)), 0.0);
    }

    #[test]
    fn f16_round_error_bound() {
        let mut rng = Pcg64::seed(10);
        for _ in 0..1000 {
            let x = rng.normal_f32();
            let y = f16_round(x);
            assert!((x - y).abs() <= x.abs() * 2f32.powi(-10) + 2f32.powi(-24));
        }
    }

    /// New resize contract under the padded layout: same shape keeps
    /// contents, any shape change zeroes the buffer (stride may differ, so
    /// flat carry-over is gone), and growth always exposes zeros.
    #[test]
    fn resize_clears_on_shape_change() {
        let mut m = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let before = m.clone();
        m.resize(2, 2); // no-op keeps contents
        assert_eq!(m, before);
        m.resize(1, 3);
        assert_eq!(m.shape(), (1, 3));
        assert_eq!(m.to_vec(), vec![0., 0., 0.]);
        let mut e = Mat::default();
        assert_eq!(e.shape(), (0, 0));
        e.resize(2, 2);
        assert_eq!(e.to_vec(), vec![0.; 4]);
        assert!(e.padding_is_clear());
    }

    #[test]
    fn mse_and_fro() {
        let a = Mat::from_vec(1, 2, vec![0., 3.]);
        let b = Mat::from_vec(1, 2, vec![4., 3.]);
        assert!((a.fro_dist2(&b) - 16.0).abs() < 1e-9);
        assert!((a.mse(&b) - 8.0).abs() < 1e-9);
    }

    /// Stride geometry: rows are padded to 8 floats and 32-byte aligned,
    /// logical accessors never see the tail.
    #[test]
    fn stride_is_padded_and_aligned() {
        for (c, s) in [(0usize, 0usize), (1, 8), (7, 8), (8, 8), (9, 16), (65, 72)] {
            let m = Mat::zeros(3, c);
            assert_eq!(m.stride(), s, "cols={c}");
            assert_eq!(m.padded().len(), 3 * s);
            assert_eq!(m.row(1).len(), c);
        }
        let m = Mat::zeros(4, 5);
        assert_eq!(m.padded().as_ptr() as usize % 32, 0);
    }

    /// Every mutating / constructing op must leave the padding tail zero —
    /// the invariant the SIMD kernels and padded reductions rely on.
    #[test]
    fn padding_stays_clear_after_every_op() {
        let mut rng = Pcg64::seed(77);
        // cols = 5: three padding floats per row to contaminate.
        let a = Mat::gaussian(6, 5, &mut rng);
        let b = Mat::gaussian(6, 5, &mut rng);
        assert!(a.padding_is_clear());
        for (name, m) in [
            ("add", a.add(&b)),
            ("sub", a.sub(&b)),
            ("scale", a.scale(-1.5)),
            ("abs", a.abs()),
            ("signum", a.signum()),
            ("scale_rows", a.scale_rows(&[1., 2., 3., 4., 5., 6.])),
            ("scale_cols", a.scale_cols(&[1., 2., 3., 4., 5.])),
            ("transpose", a.transpose()),
            ("take_cols", a.take_cols(3)),
            ("vcat", a.vcat(&b)),
            ("f16", a.to_f16_precision()),
            ("matmul", a.matmul(&b.transpose())),
            ("t_matmul", a.t_matmul(&b)),
            ("matmul_t", a.matmul_t(&b)),
            ("from_fn", Mat::from_fn(3, 5, |i, j| (i + j) as f32)),
            ("from_vec", Mat::from_vec(1, 5, vec![1.; 5])),
            ("diag", Mat::diag(&[1., 2., 3.])),
        ] {
            assert!(m.padding_is_clear(), "{name} contaminated padding");
        }
        let (t, bot) = a.vsplit(2);
        assert!(t.padding_is_clear() && bot.padding_is_clear());
        // signum on a scale(0.0) result: logical zeros become +1 but the
        // padding tail must stay zero, not +1.
        let z = a.scale(0.0).signum();
        assert!(z.padding_is_clear());
        assert!(z.to_vec().iter().all(|&v| v == 1.0));
    }

    /// `to_vec` strips padding back to the flat logical layout.
    #[test]
    fn to_vec_is_logical_row_major() {
        let m = Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f32);
        let v = m.to_vec();
        assert_eq!(v.len(), 15);
        assert!(v.iter().enumerate().all(|(i, &x)| x == i as f32));
        assert_eq!(Mat::from_vec(3, 5, v), m);
    }
}
