//! 32-byte-aligned backing buffers for the padded-stride storage layouts.
//!
//! [`Mat`](super::Mat) and [`BitMatrix`](crate::packing::BitMatrix) both pad
//! their row stride to a 32-byte boundary (8 `f32`s / 4 `u64`s) so every row
//! starts on an AVX2 vector boundary. Stable Rust has no aligned-`Vec`
//! allocator, so these buffers get their alignment structurally: the backing
//! store is a `Vec` of `#[repr(C, align(32))]` blocks, re-viewed as a flat
//! element slice. A block is exactly 32 bytes with no internal padding, so
//! `n` blocks are `8n` contiguous `f32`s (resp. `4n` `u64`s) and the slice
//! cast is layout-sound.
//!
//! Both buffers only exist in whole blocks — lengths must be multiples of
//! the block width, which the stride-padding of the owning types guarantees.

/// One 32-byte-aligned block of eight `f32`s.
#[repr(C, align(32))]
#[derive(Clone, Copy, Debug, PartialEq)]
struct F32Block([f32; 8]);

/// One 32-byte-aligned block of four `u64`s.
#[repr(C, align(32))]
#[derive(Clone, Copy, Debug, PartialEq)]
struct U64Block([u64; 4]);

/// Width of an [`AlignedF32`] block in elements.
pub const F32_BLOCK: usize = 8;

/// Width of an [`AlignedU64`] block in elements.
pub const U64_BLOCK: usize = 4;

/// 32-byte-aligned `f32` buffer; length is always a multiple of
/// [`F32_BLOCK`].
#[derive(Clone, Debug, PartialEq)]
pub struct AlignedF32 {
    blocks: Vec<F32Block>,
}

impl AlignedF32 {
    /// Zero-filled buffer of `len` elements (`len % 8 == 0`).
    pub fn zeros(len: usize) -> Self {
        assert_eq!(len % F32_BLOCK, 0, "AlignedF32 length must be a block multiple");
        Self { blocks: vec![F32Block([0.0; 8]); len / F32_BLOCK] }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.blocks.len() * F32_BLOCK
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Resize to `len` elements (`len % 8 == 0`), reusing the allocation
    /// where possible. Grown blocks are zero; carried-over blocks keep their
    /// last-written values.
    pub fn resize(&mut self, len: usize) {
        assert_eq!(len % F32_BLOCK, 0, "AlignedF32 length must be a block multiple");
        self.blocks.resize(len / F32_BLOCK, F32Block([0.0; 8]));
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        // Sound: F32Block is repr(C, align(32)) over [f32; 8] — 32 bytes,
        // no padding — so the block array is a contiguous f32 array.
        unsafe { std::slice::from_raw_parts(self.blocks.as_ptr() as *const f32, self.len()) }
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        let len = self.len();
        unsafe { std::slice::from_raw_parts_mut(self.blocks.as_mut_ptr() as *mut f32, len) }
    }
}

/// 32-byte-aligned `u64` buffer; length is always a multiple of
/// [`U64_BLOCK`].
#[derive(Clone, Debug, PartialEq)]
pub struct AlignedU64 {
    blocks: Vec<U64Block>,
}

impl AlignedU64 {
    /// Zero-filled buffer of `len` elements (`len % 4 == 0`).
    pub fn zeros(len: usize) -> Self {
        assert_eq!(len % U64_BLOCK, 0, "AlignedU64 length must be a block multiple");
        Self { blocks: vec![U64Block([0; 4]); len / U64_BLOCK] }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.blocks.len() * U64_BLOCK
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    #[inline]
    pub fn as_slice(&self) -> &[u64] {
        unsafe { std::slice::from_raw_parts(self.blocks.as_ptr() as *const u64, self.len()) }
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [u64] {
        let len = self.len();
        unsafe { std::slice::from_raw_parts_mut(self.blocks.as_mut_ptr() as *mut u64, len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_buffer_is_32_byte_aligned_and_contiguous() {
        let mut b = AlignedF32::zeros(24);
        assert_eq!(b.len(), 24);
        assert_eq!(b.as_slice().as_ptr() as usize % 32, 0);
        for (i, v) in b.as_mut_slice().iter_mut().enumerate() {
            *v = i as f32;
        }
        // Contiguity: flat writes read back in order.
        assert!(b.as_slice().iter().enumerate().all(|(i, &v)| v == i as f32));
    }

    #[test]
    fn u64_buffer_is_32_byte_aligned_and_contiguous() {
        let mut b = AlignedU64::zeros(12);
        assert_eq!(b.len(), 12);
        assert_eq!(b.as_slice().as_ptr() as usize % 32, 0);
        for (i, v) in b.as_mut_slice().iter_mut().enumerate() {
            *v = i as u64;
        }
        assert!(b.as_slice().iter().enumerate().all(|(i, &v)| v == i as u64));
    }

    #[test]
    fn resize_zero_fills_new_blocks() {
        let mut b = AlignedF32::zeros(8);
        b.as_mut_slice().fill(7.0);
        b.resize(16);
        assert_eq!(&b.as_slice()[..8], &[7.0; 8]);
        assert_eq!(&b.as_slice()[8..], &[0.0; 8]);
        b.resize(0);
        assert!(b.is_empty());
    }

    #[test]
    #[should_panic(expected = "block multiple")]
    fn non_block_length_rejected() {
        AlignedF32::zeros(5);
    }
}
