//! Householder QR and the random-orthogonal sampler built on it.
//!
//! QR serves three roles in the pipeline: (1) sampling Haar-ish random
//! orthogonal matrices for Internal Latent Rotation (§4.3) and for Joint-ITQ's
//! initial `R`; (2) re-orthonormalizing the range basis between power
//! iterations inside the randomized SVD; (3) the coherence-controlled
//! synthetic singular-vector fabricator (`spectral::synth`).

use super::Mat;
use crate::rng::Pcg64;

/// Thin Householder QR: `a (m×n, m ≥ n) = Q (m×n) · R (n×n)` with Q having
/// orthonormal columns and R upper-triangular with non-negative diagonal
/// (sign-fixed so the decomposition is unique, which also makes `Q` of a
/// gaussian exactly Haar-distributed).
pub fn householder_qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = a.shape();
    assert!(m >= n, "householder_qr expects tall matrix, got {m}x{n}");
    // Work in f64 for stability of the reflections.
    let mut r: Vec<f64> = a.as_slice().iter().map(|&x| x as f64).collect();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // reflection vectors

    for k in 0..n {
        // Build the Householder vector for column k below the diagonal.
        let mut alpha = 0.0f64;
        for i in k..m {
            let x = r[i * n + k];
            alpha += x * x;
        }
        alpha = alpha.sqrt();
        if r[k * n + k] > 0.0 {
            alpha = -alpha;
        }
        let mut v = vec![0.0f64; m - k];
        v[0] = r[k * n + k] - alpha;
        for i in k + 1..m {
            v[i - k] = r[i * n + k];
        }
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 > 1e-300 {
            // Apply H = I − 2 v vᵀ / ‖v‖² to the trailing block of R.
            for j in k..n {
                let mut dot = 0.0;
                for i in k..m {
                    dot += v[i - k] * r[i * n + j];
                }
                let c = 2.0 * dot / vnorm2;
                for i in k..m {
                    r[i * n + j] -= c * v[i - k];
                }
            }
        }
        vs.push(v);
        // Zero strictly-below-diagonal entries explicitly.
        r[k * n + k] = alpha;
        for i in k + 1..m {
            r[i * n + k] = 0.0;
        }
    }

    // Accumulate Q = H_0 H_1 ... H_{n-1} applied to the first n columns of I.
    let mut q = vec![0.0f64; m * n];
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 <= 1e-300 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[i * n + j];
            }
            let c = 2.0 * dot / vnorm2;
            for i in k..m {
                q[i * n + j] -= c * v[i - k];
            }
        }
    }

    // Sign-fix: make diag(R) non-negative.
    for k in 0..n {
        if r[k * n + k] < 0.0 {
            for j in k..n {
                r[k * n + j] = -r[k * n + j];
            }
            for i in 0..m {
                q[i * n + k] = -q[i * n + k];
            }
        }
    }

    let qm = Mat::from_vec(m, n, q.iter().map(|&x| x as f32).collect());
    let rm = Mat::from_vec(n, n, r[..n * n].to_vec().iter().map(|&x| x as f32).collect());
    (qm, rm)
}

/// Haar-distributed random orthogonal `n×n` matrix: QR of a gaussian with the
/// sign-fixed R (Mezzadri, 2007). This is the paper's
/// `torch.nn.init.orthogonal_` equivalent.
pub fn random_orthogonal(n: usize, rng: &mut Pcg64) -> Mat {
    let g = Mat::gaussian(n, n, rng);
    let (q, _r) = householder_qr(&g);
    q
}

/// ‖QᵀQ − I‖_F — orthogonality defect, used by tests and by the coordinator's
/// self-checks after each ITQ solve.
pub fn orthogonality_defect(q: &Mat) -> f64 {
    let qtq = q.t_matmul(q);
    let n = qtq.rows();
    let mut acc = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let target = if i == j { 1.0 } else { 0.0 };
            let d = qtq.at(i, j) as f64 - target;
            acc += d * d;
        }
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::seed(1);
        let a = Mat::gaussian(20, 8, &mut rng);
        let (q, r) = householder_qr(&a);
        let back = q.matmul(&r);
        assert!(back.fro_dist2(&a) < 1e-6, "dist={}", back.fro_dist2(&a));
    }

    #[test]
    fn qr_q_is_orthonormal() {
        let mut rng = Pcg64::seed(2);
        let a = Mat::gaussian(50, 50, &mut rng);
        let (q, _) = householder_qr(&a);
        assert!(orthogonality_defect(&q) < 1e-4);
    }

    #[test]
    fn qr_r_is_upper_triangular_nonneg_diag() {
        let mut rng = Pcg64::seed(3);
        let a = Mat::gaussian(12, 6, &mut rng);
        let (_, r) = householder_qr(&a);
        for i in 0..6 {
            assert!(r.at(i, i) >= 0.0);
            for j in 0..i {
                assert!(r.at(i, j).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn random_orthogonal_is_orthogonal() {
        let mut rng = Pcg64::seed(4);
        for n in [3, 16, 64] {
            let q = random_orthogonal(n, &mut rng);
            assert!(orthogonality_defect(&q) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn random_orthogonal_preserves_norms() {
        let mut rng = Pcg64::seed(5);
        let q = random_orthogonal(32, &mut rng);
        let x = Mat::gaussian(1, 32, &mut rng);
        let y = x.matmul(&q);
        assert!((x.fro_norm() - y.fro_norm()).abs() < 1e-4);
    }

    #[test]
    fn haar_rotation_delocalizes_a_spike() {
        // A coordinate-axis spike rotated by Haar Q should spread its mass:
        // L1/L2 ratio grows from 1 toward sqrt(2n/pi) (Theorem 4.4).
        let mut rng = Pcg64::seed(6);
        let n = 256;
        let q = random_orthogonal(n, &mut rng);
        let mut e = vec![0.0f32; n];
        e[0] = 1.0;
        let y = Mat::from_vec(1, n, e).matmul(&q);
        let ratio = crate::linalg::norm1(y.row(0)) / crate::linalg::norm2(y.row(0));
        let expect = (2.0 * n as f64 / std::f64::consts::PI).sqrt();
        assert!(ratio > 0.8 * expect, "ratio={ratio} expect≈{expect}");
    }
}
