//! Householder QR and the random-orthogonal sampler built on it.
//!
//! QR serves three roles in the pipeline: (1) sampling Haar-ish random
//! orthogonal matrices for Internal Latent Rotation (§4.3) and for Joint-ITQ's
//! initial `R`; (2) re-orthonormalizing the range basis between power
//! iterations inside the randomized SVD; (3) the coherence-controlled
//! synthetic singular-vector fabricator (`spectral::synth`).
//!
//! The factorization works on **column-major** f64 scratch: every
//! Householder reflection touches whole columns, so column-major makes each
//! update a contiguous streak AND lets the trailing-column updates split
//! into disjoint `&mut` column chunks for the pool
//! ([`householder_qr_on`]). Per column the reflection arithmetic (dot,
//! then axpy, both in ascending row order) is identical in every chunk, so
//! the pooled factorization is bit-identical to the serial one for any
//! thread count.

use super::Mat;
use crate::parallel::Pool;
use crate::rng::Pcg64;

/// Minimum trailing-update size (`columns × active rows`) before a
/// reflection is worth splitting across the pool. Shape-only, hence
/// deterministic; bit-irrelevant either way.
const PAR_MIN_CELLS: usize = 32 * 1024;

/// Apply the reflection `H = I − 2 v vᵀ / ‖v‖²` to the active tail of one
/// column (both loops ascend in row order — the source of bit-exactness
/// across any column partitioning).
#[inline]
fn reflect(col: &mut [f64], v: &[f64], vnorm2: f64) {
    let mut dot = 0.0f64;
    for (a, b) in v.iter().zip(col.iter()) {
        dot += a * b;
    }
    let c = 2.0 * dot / vnorm2;
    for (a, b) in v.iter().zip(col.iter_mut()) {
        *b -= c * a;
    }
}

/// Thin Householder QR: `a (m×n, m ≥ n) = Q (m×n) · R (n×n)` with Q having
/// orthonormal columns and R upper-triangular with non-negative diagonal
/// (sign-fixed so the decomposition is unique, which also makes `Q` of a
/// gaussian exactly Haar-distributed). Serial entry;
/// [`householder_qr_on`] is the pool-parallel twin (bit-identical).
pub fn householder_qr(a: &Mat) -> (Mat, Mat) {
    householder_qr_on(a, Pool::serial())
}

/// [`householder_qr`] with the per-reflection trailing-column updates (and
/// the Q back-accumulation) partitioned into contiguous column chunks
/// across `pool`. Columns are updated independently with fixed row-order
/// arithmetic, so the result is bit-identical to the serial factorization
/// for any thread count.
pub fn householder_qr_on(a: &Mat, pool: &Pool) -> (Mat, Mat) {
    let (m, n) = a.shape();
    assert!(m >= n, "householder_qr expects tall matrix, got {m}x{n}");
    // Column-major f64 work matrix: column j occupies r[j*m..(j+1)*m].
    let mut r = vec![0.0f64; m * n];
    for j in 0..n {
        for i in 0..m {
            r[j * m + i] = a.at(i, j) as f64;
        }
    }
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // reflection vectors

    for k in 0..n {
        // Build the Householder vector for column k below the diagonal.
        let col = &r[k * m..(k + 1) * m];
        let mut alpha = 0.0f64;
        for &x in &col[k..] {
            alpha += x * x;
        }
        alpha = alpha.sqrt();
        if col[k] > 0.0 {
            alpha = -alpha;
        }
        let mut v = vec![0.0f64; m - k];
        v[0] = col[k] - alpha;
        v[1..].copy_from_slice(&col[k + 1..]);
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 > 1e-300 {
            // Apply H to the trailing columns k+1..n, chunked on the pool.
            // (Column k itself collapses to (…, alpha, 0, …, 0) by
            // construction; that is written directly below.)
            let tail = &mut r[(k + 1) * m..];
            let parts = if (n - k - 1) * (m - k) < PAR_MIN_CELLS { 1 } else { pool.threads() };
            pool.run_row_chunks(tail, m, parts, |_, cols| {
                for col in cols.chunks_mut(m) {
                    reflect(&mut col[k..], &v, vnorm2);
                }
            });
        }
        r[k * m + k] = alpha;
        for i in k + 1..m {
            r[k * m + i] = 0.0;
        }
        vs.push(v);
    }

    // Accumulate Q = H_0 H_1 ... H_{n-1} applied to the first n columns of
    // I, same column-chunked dispatch.
    let mut q = vec![0.0f64; m * n];
    for j in 0..n {
        q[j * m + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 <= 1e-300 {
            continue;
        }
        let parts = if n * (m - k) < PAR_MIN_CELLS { 1 } else { pool.threads() };
        pool.run_row_chunks(&mut q, m, parts, |_, cols| {
            for col in cols.chunks_mut(m) {
                reflect(&mut col[k..], v, vnorm2);
            }
        });
    }

    // Sign-fix: make diag(R) non-negative.
    for k in 0..n {
        if r[k * m + k] < 0.0 {
            for j in k..n {
                r[j * m + k] = -r[j * m + k];
            }
            for i in 0..m {
                q[k * m + i] = -q[k * m + i];
            }
        }
    }

    let qm = Mat::from_fn(m, n, |i, j| q[j * m + i] as f32);
    let rm = Mat::from_fn(n, n, |i, j| r[j * m + i] as f32);
    (qm, rm)
}

/// Haar-distributed random orthogonal `n×n` matrix: QR of a gaussian with the
/// sign-fixed R (Mezzadri, 2007). This is the paper's
/// `torch.nn.init.orthogonal_` equivalent.
pub fn random_orthogonal(n: usize, rng: &mut Pcg64) -> Mat {
    let g = Mat::gaussian(n, n, rng);
    let (q, _r) = householder_qr(&g);
    q
}

/// ‖QᵀQ − I‖_F — orthogonality defect, used by tests and by the coordinator's
/// self-checks after each ITQ solve.
pub fn orthogonality_defect(q: &Mat) -> f64 {
    let qtq = q.t_matmul(q);
    let n = qtq.rows();
    let mut acc = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let target = if i == j { 1.0 } else { 0.0 };
            let d = qtq.at(i, j) as f64 - target;
            acc += d * d;
        }
    }
    acc.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg64::seed(1);
        let a = Mat::gaussian(20, 8, &mut rng);
        let (q, r) = householder_qr(&a);
        let back = q.matmul(&r);
        assert!(back.fro_dist2(&a) < 1e-6, "dist={}", back.fro_dist2(&a));
    }

    #[test]
    fn qr_q_is_orthonormal() {
        let mut rng = Pcg64::seed(2);
        let a = Mat::gaussian(50, 50, &mut rng);
        let (q, _) = householder_qr(&a);
        assert!(orthogonality_defect(&q) < 1e-4);
    }

    #[test]
    fn qr_r_is_upper_triangular_nonneg_diag() {
        let mut rng = Pcg64::seed(3);
        let a = Mat::gaussian(12, 6, &mut rng);
        let (_, r) = householder_qr(&a);
        for i in 0..6 {
            assert!(r.at(i, i) >= 0.0);
            for j in 0..i {
                assert!(r.at(i, j).abs() < 1e-6);
            }
        }
    }

    /// The pooled factorization must be bit-identical to the serial one —
    /// shapes chosen so the trailing updates actually cross the dispatch
    /// threshold.
    #[test]
    fn pooled_qr_matches_serial_bit_exactly() {
        let mut rng = Pcg64::seed(7);
        let a = Mat::gaussian(300, 130, &mut rng);
        let (q0, r0) = householder_qr(&a);
        for threads in [2usize, 7] {
            let pool = Pool::new(threads);
            let (q1, r1) = householder_qr_on(&a, &pool);
            assert_eq!(q0, q1, "Q differs at threads={threads}");
            assert_eq!(r0, r1, "R differs at threads={threads}");
        }
    }

    #[test]
    fn random_orthogonal_is_orthogonal() {
        let mut rng = Pcg64::seed(4);
        for n in [3, 16, 64] {
            let q = random_orthogonal(n, &mut rng);
            assert!(orthogonality_defect(&q) < 1e-4, "n={n}");
        }
    }

    #[test]
    fn random_orthogonal_preserves_norms() {
        let mut rng = Pcg64::seed(5);
        let q = random_orthogonal(32, &mut rng);
        let x = Mat::gaussian(1, 32, &mut rng);
        let y = x.matmul(&q);
        assert!((x.fro_norm() - y.fro_norm()).abs() < 1e-4);
    }

    #[test]
    fn haar_rotation_delocalizes_a_spike() {
        // A coordinate-axis spike rotated by Haar Q should spread its mass:
        // L1/L2 ratio grows from 1 toward sqrt(2n/pi) (Theorem 4.4).
        let mut rng = Pcg64::seed(6);
        let n = 256;
        let q = random_orthogonal(n, &mut rng);
        let mut e = vec![0.0f32; n];
        e[0] = 1.0;
        let y = Mat::from_vec(1, n, e).matmul(&q);
        let ratio = crate::linalg::norm1(y.row(0)) / crate::linalg::norm2(y.row(0));
        let expect = (2.0 * n as f64 / std::f64::consts::PI).sqrt();
        assert!(ratio > 0.8 * expect, "ratio={ratio} expect≈{expect}");
    }
}
