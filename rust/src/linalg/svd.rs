//! Singular value decomposition: one-sided Jacobi (exact, small matrices)
//! and Halko–Martinsson–Tropp randomized truncation (big weight matrices).
//!
//! Shapes in this codebase:
//! * Joint-ITQ's Procrustes step needs the **full** SVD of an `r×r` system
//!   (`r ≤ ~1024`) → [`svd_jacobi`].
//! * Dual-SVID needs the **top-r** SVD of `d_out×d_in` weights (`d ≈ 4096`)
//!   → [`svd_randomized`] with oversampling + power iterations.
//! * Rank-1 magnitude decomposition (`|U| ≈ h·lᵀ`) → [`svd_randomized`] with
//!   `rank = 1` (power iteration dominated; very fast).

use super::{householder_qr, householder_qr_on, Mat};
use crate::parallel::Pool;
use crate::rng::Pcg64;

/// A (possibly truncated) SVD `a ≈ u · diag(s) · vᵀ`.
///
/// `u` is `m×r`, `s` length-`r` descending, `v` is `n×r` (so `vᵀ` is `r×n`).
#[derive(Clone, Debug)]
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f32>,
    pub v: Mat,
}

impl Svd {
    /// Reconstruct `u · diag(s) · vᵀ`.
    pub fn reconstruct(&self) -> Mat {
        self.u.scale_cols(&self.s).matmul_t(&self.v)
    }

    /// Truncate to the top `r` components.
    pub fn truncate(&self, r: usize) -> Svd {
        let r = r.min(self.s.len());
        Svd {
            u: self.u.take_cols(r),
            s: self.s[..r].to_vec(),
            v: self.v.take_cols(r),
        }
    }

    /// Split singular values symmetrically: returns `(û, v̂)` with
    /// `û = u·diag(√s)`, `v̂ = v·diag(√s)` so `a ≈ û · v̂ᵀ` (Alg 2, step 7).
    pub fn split_factors(&self) -> (Mat, Mat) {
        let sq: Vec<f32> = self.s.iter().map(|x| x.max(0.0).sqrt()).collect();
        (self.u.scale_cols(&sq), self.v.scale_cols(&sq))
    }
}

/// One-sided Jacobi SVD of a general (small) matrix. Exact to working
/// precision; `O(n³)` per sweep, converges in ~5–10 sweeps.
///
/// Works on `m×n` with `m ≥ n` (transpose internally otherwise).
pub fn svd_jacobi(a: &Mat) -> Svd {
    let (m, n) = a.shape();
    if m < n {
        // SVD(Aᵀ) = (V, S, U).
        let t = svd_jacobi(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }

    // Work matrix in f64: columns get rotated until mutually orthogonal.
    let mut w: Vec<f64> = a.to_vec().iter().map(|&x| x as f64).collect();
    let stride = n;
    let eps = 1e-13;
    let max_sweeps = 60;

    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Compute the 2x2 Gram block for columns p, q.
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let x = w[i * stride + p];
                    let y = w[i * stride + q];
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                off += apq * apq;
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation annihilating the off-diagonal.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let x = w[i * stride + p];
                    let y = w[i * stride + q];
                    w[i * stride + p] = c * x - s * y;
                    w[i * stride + q] = s * x + c * y;
                }
            }
        }
        if off.sqrt() < eps * m as f64 {
            break;
        }
    }

    // Column norms are the singular values; normalized columns are U.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sv = vec![0.0f64; n];
    for (j, s) in sv.iter_mut().enumerate() {
        let mut acc = 0.0;
        for i in 0..m {
            let x = w[i * stride + j];
            acc += x * x;
        }
        *s = acc.sqrt();
    }
    order.sort_by(|&i, &j| sv[j].partial_cmp(&sv[i]).expect("finite"));

    let mut u = Mat::zeros(m, n);
    let mut s_out = vec![0.0f32; n];
    for (jj, &j) in order.iter().enumerate() {
        s_out[jj] = sv[j] as f32;
        if sv[j] > 1e-300 {
            for i in 0..m {
                *u.at_mut(i, jj) = (w[i * stride + j] / sv[j]) as f32;
            }
        } else if m == n {
            // Null column: leave zero (caller never scales by it).
        }
    }

    // V from vᵀ = diag(1/s) uᵀ a → columns of V solve a v_j = s_j u_j.
    // Since the one-sided rotations were accumulated in the columns of W,
    // V is exactly the product of the applied rotations on the identity; we
    // recover it more simply as V = aᵀ u diag(1/s) (numerically fine for
    // non-degenerate spectra, then re-orthonormalized).
    let mut v = a.t_matmul(&u); // n×n
    for j in 0..n {
        let s = s_out[j];
        if s > 1e-30 {
            for i in 0..n {
                *v.at_mut(i, j) /= s;
            }
        }
    }
    // Light re-orthonormalization to clean up near-degenerate directions.
    let (v, _) = householder_qr(&v);
    // QR's sign fix may flip columns of V; re-align with the residual
    // aᵀu s (flip where the dot is negative).
    let target = a.t_matmul(&u);
    let mut v = v;
    for j in 0..n {
        let mut dot = 0.0f64;
        for i in 0..n {
            dot += (v.at(i, j) as f64) * (target.at(i, j) as f64);
        }
        if dot < 0.0 {
            for i in 0..n {
                *v.at_mut(i, j) = -v.at(i, j);
            }
        }
    }

    Svd { u, s: s_out, v }
}

/// Randomized truncated SVD (HMT 2011, Alg 4.4 + 5.1).
///
/// `rank` — target rank; `oversample` — extra range dims (≥8 recommended);
/// `power_iters` — subspace iterations (2 suffices for power-law spectra).
///
/// Runs on the process-wide [`Pool::global`]: the range-finding products
/// and QR re-orthonormalizations — the compression pipeline's dominant
/// cost on `d×d` weights — split across output rows/columns, bit-identical
/// to the serial path for any thread count. Use
/// [`svd_randomized_on`] to pin an explicit pool (e.g. `Pool::serial()`).
pub fn svd_randomized(
    a: &Mat,
    rank: usize,
    oversample: usize,
    power_iters: usize,
    rng: &mut Pcg64,
) -> Svd {
    svd_randomized_on(a, rank, oversample, power_iters, rng, Pool::global())
}

/// [`svd_randomized`] on an explicit [`Pool`]. Bit-identical results for
/// any pool (the dense products and QR keep fixed per-element reduction
/// orders); the pool only changes wall-clock.
pub fn svd_randomized_on(
    a: &Mat,
    rank: usize,
    oversample: usize,
    power_iters: usize,
    rng: &mut Pcg64,
    pool: &Pool,
) -> Svd {
    let (m, n) = a.shape();
    let r = rank.min(m.min(n));
    let l = (r + oversample).min(n.min(m));

    // Range finding: Y = A Ω, then power iterations with QR stabilization.
    let omega = Mat::gaussian(n, l, rng);
    let mut y = a.matmul_on(&omega, pool); // m×l
    let (mut q, _) = householder_qr_on(&y, pool);
    for _ in 0..power_iters {
        let z = a.t_matmul_on(&q, pool); // n×l
        let (qz, _) = householder_qr_on(&z, pool);
        y = a.matmul_on(&qz, pool); // m×l
        let (q2, _) = householder_qr_on(&y, pool);
        q = q2;
    }

    // Project: B = Qᵀ A (l×n), small SVD of Bᵀ (n×l) via Jacobi.
    let b = q.t_matmul_on(a, pool); // l×n
    let small = svd_jacobi(&b); // b = us vᵀ with u l×l
    let u = q.matmul_on(&small.u.take_cols(r), pool); // m×r
    Svd {
        u,
        s: small.s[..r].to_vec(),
        v: small.v.take_cols(r),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn low_rank_matrix(m: usize, n: usize, r: usize, rng: &mut Pcg64) -> Mat {
        let u = Mat::gaussian(m, r, rng);
        let v = Mat::gaussian(r, n, rng);
        u.matmul(&v)
    }

    #[test]
    fn jacobi_reconstructs_small() {
        let mut rng = Pcg64::seed(1);
        let a = Mat::gaussian(10, 6, &mut rng);
        let svd = svd_jacobi(&a);
        let back = svd.reconstruct();
        assert!(back.fro_dist2(&a) / a.fro_norm().powi(2) < 1e-8);
    }

    #[test]
    fn jacobi_square_and_wide() {
        let mut rng = Pcg64::seed(2);
        for (m, n) in [(8, 8), (6, 12)] {
            let a = Mat::gaussian(m, n, &mut rng);
            let svd = svd_jacobi(&a);
            assert!(svd.reconstruct().fro_dist2(&a) / a.fro_norm().powi(2) < 1e-7, "{m}x{n}");
        }
    }

    #[test]
    fn jacobi_singular_values_descending_nonneg() {
        let mut rng = Pcg64::seed(3);
        let a = Mat::gaussian(20, 10, &mut rng);
        let svd = svd_jacobi(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(svd.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn jacobi_orthonormal_factors() {
        let mut rng = Pcg64::seed(4);
        let a = Mat::gaussian(15, 7, &mut rng);
        let svd = svd_jacobi(&a);
        assert!(crate::linalg::orthogonality_defect(&svd.u) < 1e-4);
        assert!(crate::linalg::orthogonality_defect(&svd.v) < 1e-4);
    }

    #[test]
    fn jacobi_matches_known_diagonal() {
        let a = Mat::diag(&[3.0, 1.0, 2.0]);
        let svd = svd_jacobi(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-5);
        assert!((svd.s[1] - 2.0).abs() < 1e-5);
        assert!((svd.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn randomized_recovers_exact_low_rank() {
        let mut rng = Pcg64::seed(5);
        let a = low_rank_matrix(120, 80, 10, &mut rng);
        let svd = svd_randomized(&a, 10, 8, 2, &mut rng);
        let back = svd.reconstruct();
        assert!(back.fro_dist2(&a) / a.fro_norm().powi(2) < 1e-6);
    }

    #[test]
    fn randomized_near_optimal_on_decaying_spectrum() {
        let mut rng = Pcg64::seed(6);
        // Build a matrix with known singular values k^{-0.8}.
        let n = 96;
        let q1 = crate::linalg::random_orthogonal(n, &mut rng);
        let q2 = crate::linalg::random_orthogonal(n, &mut rng);
        let s: Vec<f32> = (1..=n).map(|k| (k as f32).powf(-0.8)).collect();
        let a = q1.scale_cols(&s).matmul_t(&q2);
        let r = 16;
        let svd = svd_randomized(&a, r, 10, 3, &mut rng);
        // Optimal truncation error (Eckart–Young).
        let opt: f64 = s[r..].iter().map(|&x| (x as f64).powi(2)).sum();
        let err = svd.reconstruct().fro_dist2(&a);
        assert!(err < opt * 1.2 + 1e-9, "err={err} opt={opt}");
        // Singular value estimates close to truth.
        for k in 0..4 {
            assert!((svd.s[k] - s[k]).abs() / s[k] < 0.02, "k={k}");
        }
    }

    #[test]
    fn rank1_magnitude_decomposition_shape() {
        let mut rng = Pcg64::seed(7);
        let a = Mat::gaussian(40, 12, &mut rng).abs();
        let svd = svd_randomized(&a, 1, 6, 3, &mut rng);
        assert_eq!(svd.u.shape(), (40, 1));
        assert_eq!(svd.v.shape(), (12, 1));
        // Rank-1 of a positive matrix: factors should be single-signed.
        let u = svd.u.to_vec();
        let all_same_sign =
            u.iter().all(|&x| x >= -1e-6) || u.iter().all(|&x| x <= 1e-6);
        assert!(all_same_sign);
    }

    #[test]
    fn split_factors_reconstruct() {
        let mut rng = Pcg64::seed(8);
        let a = low_rank_matrix(30, 20, 5, &mut rng);
        let svd = svd_randomized(&a, 5, 8, 2, &mut rng);
        let (u, v) = svd.split_factors();
        let back = u.matmul_t(&v);
        assert!(back.fro_dist2(&a) / a.fro_norm().powi(2) < 1e-5);
    }
}
