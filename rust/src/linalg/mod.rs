//! Dense linear algebra substrate.
//!
//! The paper's entire initialization pipeline is matrix algebra: truncated
//! SVD (Dual-SVID, Alg 2), QR (random orthogonal rotations), the Procrustes
//! solve inside Joint-ITQ (SVD of `BᵀZ`), and rank-1 magnitude decomposition.
//! No BLAS/LAPACK is linked in this environment, so this module provides a
//! self-contained, tested implementation tuned for the shapes the pipeline
//! actually hits:
//!
//! * `matmul` — cache-blocked, `f32` storage with per-tile accumulation.
//! * `qr` — Householder on column-major scratch, used both for
//!   orthonormalization and for the random-orthogonal sampler.
//! * `svd_jacobi` — one-sided Jacobi, cubic but rock-solid; used on small
//!   square matrices (the `r×r` Procrustes systems, `r ≤ ~1024`).
//! * `svd_randomized` — Halko–Martinsson–Tropp randomized range finder with
//!   power iterations; used for rank-`r` truncation of the big weight
//!   matrices (`d×d`, `d` up to 4096+ here).
//!
//! The heavy kernels (`Mat::{matmul, t_matmul, matmul_t, matvec}`, the QR
//! trailing updates, the randomized-SVD products) come in `_on` variants
//! that partition output rows over a [`crate::parallel::Pool`]. Every
//! output element keeps a fixed reduction order, so pooled results are
//! **bit-identical** to serial for any thread count — the invariant the
//! whole compression pipeline's `--jobs N` determinism rests on
//! (`tests/parallel_linalg.rs`). `svd_randomized` defaults to the shared
//! global pool; the plain `Mat` entry points stay serial.
//!
//! Storage is row-major `f32`; accumulations are `f32` with `f64` reductions
//! where precision matters (norms, dot products over long vectors).

pub mod aligned;
mod mat;
mod qr;
mod svd;

pub use aligned::{AlignedF32, AlignedU64};
pub use mat::{f16_round, Mat};
pub use qr::{householder_qr, householder_qr_on, orthogonality_defect, random_orthogonal};
pub use svd::{svd_jacobi, svd_randomized, svd_randomized_on, Svd};

/// Dot product with f64 accumulation.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        acc += (*x as f64) * (*y as f64);
    }
    acc
}

/// Euclidean norm with f64 accumulation.
#[inline]
pub fn norm2(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

/// L1 norm with f64 accumulation.
#[inline]
pub fn norm1(a: &[f32]) -> f64 {
    a.iter().map(|x| x.abs() as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn dot_matches_naive() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, -5.0, 6.0];
        assert!((dot(&a, &b) - 12.0).abs() < 1e-9);
    }

    #[test]
    fn norms() {
        let a = [3.0f32, -4.0];
        assert!((norm2(&a) - 5.0).abs() < 1e-9);
        assert!((norm1(&a) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn norm_of_random_gaussian_concentrates() {
        let mut rng = Pcg64::seed(1);
        let mut v = vec![0.0f32; 4096];
        rng.fill_normal(&mut v);
        let n = norm2(&v);
        assert!((n - 64.0).abs() < 3.0, "norm={n}");
    }
}
