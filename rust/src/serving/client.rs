//! Blocking wire-protocol client — the test harness's and CLI's view of
//! the server. Deliberately symmetric with the server reader: header
//! first, declared length capped before allocation, CRC checked, and only
//! server→client frame kinds accepted.
//!
//! Two layers:
//!
//! - [`WireClient`]: one connection, no recovery — any BUSY frame, CRC
//!   mismatch, or socket error is a terminal `Err`. Generic over the
//!   stream so the chaos harness can drive it over a
//!   [`FaultyStream`](crate::faults::FaultyStream).
//! - [`RetryingClient`]: a [`WireClient`] plus a [`RetryPolicy`] —
//!   exponential backoff with seeded jitter, BUSY retry-after hints
//!   honored, broken connections reconnected and unanswered requests
//!   resent by id. Retries are invisible in the answers: the server's
//!   outputs are deterministic, so a resent request returns bits
//!   identical to what the first attempt would have.

use super::frame::{
    err_code, frame_crc, parse_header, payload_f32, Frame, FrameKind, CRC_OFFSET,
    DEFAULT_MAX_PAYLOAD, HEADER_LEN,
};
use crate::coordinator::HealthState;
use crate::rng::Pcg64;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// One blocking connection to a [`TcpFrontend`](super::TcpFrontend).
pub struct WireClient<S: Read + Write = TcpStream> {
    stream: S,
    max_payload: usize,
}

impl WireClient<TcpStream> {
    /// Connect with a 30 s read timeout (a wedged server surfaces as an
    /// `Err`, not a hang).
    pub fn connect(addr: impl ToSocketAddrs) -> anyhow::Result<Self> {
        Self::connect_with_timeout(addr, Duration::from_secs(30))
    }

    /// Connect with an explicit per-read timeout.
    pub fn connect_with_timeout(
        addr: impl ToSocketAddrs,
        read_timeout: Duration,
    ) -> anyhow::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(read_timeout))?;
        Ok(Self { stream, max_payload: DEFAULT_MAX_PAYLOAD })
    }
}

impl<S: Read + Write> WireClient<S> {
    /// Build a client over an arbitrary stream — how the chaos harness
    /// speaks the protocol through a fault-injected wrapper.
    pub fn over(stream: S) -> Self {
        Self { stream, max_payload: DEFAULT_MAX_PAYLOAD }
    }

    /// Send any frame (pipelining: responses arrive via [`recv`](Self::recv)
    /// in server completion order, matched by id).
    pub fn send(&mut self, frame: &Frame) -> anyhow::Result<()> {
        self.stream.write_all(&frame.encode())?;
        Ok(())
    }

    /// Send one INFER frame without waiting for its response.
    pub fn send_infer(&mut self, id: u64, input: &[f32], deadline_ms: u32) -> anyhow::Result<()> {
        self.send(&Frame::infer(id, input, deadline_ms))
    }

    /// Receive the next server frame (CRC-checked; only server→client
    /// kinds accepted).
    pub fn recv(&mut self) -> anyhow::Result<Frame> {
        let mut header = [0u8; HEADER_LEN];
        self.stream.read_exact(&mut header)?;
        let h = parse_header(&header, self.max_payload)?;
        let mut payload = vec![0u8; h.len];
        self.stream.read_exact(&mut payload)?;
        let got = frame_crc(&header[..CRC_OFFSET], &payload);
        if got != h.crc {
            anyhow::bail!("response frame CRC mismatch (expected {:08x}, got {got:08x})", h.crc);
        }
        match h.kind {
            FrameKind::Result
            | FrameKind::Error
            | FrameKind::Busy
            | FrameKind::StatsText
            | FrameKind::ShutdownAck
            | FrameKind::HealthReport => {}
            other => anyhow::bail!("unexpected server frame kind {other:?}"),
        }
        Ok(Frame { kind: h.kind, id: h.id, aux: h.aux, payload })
    }

    /// Blocking single request: send INFER, wait for its frame, return the
    /// output column. BUSY, ERROR, and id mismatches are `Err`.
    pub fn infer(&mut self, id: u64, input: &[f32], deadline_ms: u32) -> anyhow::Result<Vec<f32>> {
        self.send_infer(id, input, deadline_ms)?;
        let f = self.recv()?;
        match f.kind {
            FrameKind::Result => {
                if f.id != id {
                    anyhow::bail!("response id {} for request id {id}", f.id);
                }
                Ok(payload_f32(&f.payload)?)
            }
            FrameKind::Busy => anyhow::bail!("server busy (admission control rejected {id})"),
            FrameKind::Error => anyhow::bail!(
                "server error {} on request {}: {}",
                error_name(f.aux),
                f.id,
                String::from_utf8_lossy(&f.payload)
            ),
            other => anyhow::bail!("unexpected reply kind {other:?} to INFER"),
        }
    }

    /// Fetch the Prometheus-style metrics text.
    pub fn stats_text(&mut self) -> anyhow::Result<String> {
        self.send(&Frame::stats(0))?;
        let f = self.recv()?;
        match f.kind {
            FrameKind::StatsText => Ok(String::from_utf8_lossy(&f.payload).into_owned()),
            other => anyhow::bail!("unexpected reply kind {other:?} to STATS"),
        }
    }

    /// Probe server health: send HEALTH, return the reported state.
    pub fn health(&mut self) -> anyhow::Result<HealthState> {
        self.send(&Frame::health(0))?;
        let f = self.recv()?;
        match f.kind {
            FrameKind::HealthReport => HealthState::from_code(f.aux)
                .ok_or_else(|| anyhow::anyhow!("unknown health code {}", f.aux)),
            other => anyhow::bail!("unexpected reply kind {other:?} to HEALTH"),
        }
    }

    /// Ask the server to shut down gracefully; waits for the ack.
    pub fn shutdown_server(&mut self) -> anyhow::Result<()> {
        self.send(&Frame::shutdown(0))?;
        let f = self.recv()?;
        match f.kind {
            FrameKind::ShutdownAck => Ok(()),
            other => anyhow::bail!("unexpected reply kind {other:?} to SHUTDOWN"),
        }
    }
}

/// Human-readable name for an ERROR frame's aux code.
pub fn error_name(code: u32) -> &'static str {
    match code {
        err_code::PROTOCOL => "PROTOCOL",
        err_code::BAD_REQUEST => "BAD_REQUEST",
        err_code::BACKEND => "BACKEND",
        err_code::DEADLINE => "DEADLINE",
        err_code::SHUTTING_DOWN => "SHUTTING_DOWN",
        _ => "UNKNOWN",
    }
}

/// Retry behavior for [`RetryingClient`]: exponential backoff with seeded
/// jitter and an optional wall-clock budget. All randomness comes from
/// `jitter_seed`, so a retry sequence — like everything else in this
/// crate — is reproducible.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Rounds before giving up. One round sends every unanswered request
    /// once; the first round counts.
    pub max_attempts: usize,
    /// Backoff before round 2 (doubles each round, capped at
    /// `max_backoff`).
    pub base_backoff: Duration,
    pub max_backoff: Duration,
    /// Overall wall-clock budget across all rounds; `None` = bounded by
    /// `max_attempts` alone.
    pub budget: Option<Duration>,
    /// Per-read socket timeout applied by the built-in connector, so a
    /// dead server costs one timeout, not a 30 s hang per round.
    pub op_timeout: Duration,
    /// Seed for backoff jitter (decorrelates clients that fail together).
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 5,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(500),
            budget: None,
            op_timeout: Duration::from_secs(5),
            jitter_seed: 0x5EED,
        }
    }
}

impl RetryPolicy {
    /// Backoff before round `attempt + 1` (attempt counts completed
    /// rounds): exponential with cap, jittered to 50–100% of nominal.
    fn backoff(&self, attempt: usize, rng: &mut Pcg64) -> Duration {
        let exp = self
            .base_backoff
            .saturating_mul(1u32 << attempt.min(16).min(31) as u32)
            .min(self.max_backoff);
        let half = exp.as_secs_f64() / 2.0;
        Duration::from_secs_f64(half + rng.uniform() * half)
    }
}

/// How one send/receive round ended (fatal errors return early instead).
enum RoundOutcome {
    /// The connection survived the round; `hint_ms` is the largest BUSY
    /// retry-after received (0 if none).
    Progress { hint_ms: u32 },
    /// The connection died (send/recv error) — reconnect next round.
    ConnLost,
}

type Connector<S> = Box<dyn FnMut() -> anyhow::Result<WireClient<S>> + Send>;

/// A self-healing wire client: wraps a [`WireClient`] with reconnect and
/// retry per its [`RetryPolicy`]. Requests are identified by caller ids
/// (which must be unique within one call), so a retried request is the
/// *same* request to the server's accounting, and — the server being
/// deterministic — returns the same bits on whichever attempt succeeds.
pub struct RetryingClient<S: Read + Write = TcpStream> {
    policy: RetryPolicy,
    rng: Pcg64,
    conn: Option<WireClient<S>>,
    connect: Connector<S>,
    connected_once: bool,
    /// Requests that needed more than one round (counter, for reporting).
    pub retried: u64,
    /// Successful reconnects after a lost connection (counter).
    pub reconnects: u64,
}

impl RetryingClient<TcpStream> {
    /// Retrying client over real TCP connections to `addr`.
    pub fn connect(
        addr: impl ToSocketAddrs + Clone + Send + 'static,
        policy: RetryPolicy,
    ) -> Self {
        let op_timeout = policy.op_timeout;
        Self::with_connector(policy, move || {
            WireClient::connect_with_timeout(addr.clone(), op_timeout)
        })
    }
}

impl<S: Read + Write> RetryingClient<S> {
    /// Retrying client over a custom connector — how the chaos harness
    /// dials through client-side [`FaultyStream`](crate::faults::FaultyStream)s.
    pub fn with_connector(
        policy: RetryPolicy,
        connect: impl FnMut() -> anyhow::Result<WireClient<S>> + Send + 'static,
    ) -> Self {
        let rng = Pcg64::seed(policy.jitter_seed);
        Self {
            policy,
            rng,
            conn: None,
            connect: Box::new(connect),
            connected_once: false,
            retried: 0,
            reconnects: 0,
        }
    }

    /// One request with retries; see [`infer_many`](Self::infer_many).
    pub fn infer(&mut self, id: u64, input: &[f32], deadline_ms: u32) -> anyhow::Result<Vec<f32>> {
        let mut out = self.infer_many(&[(id, input.to_vec())], deadline_ms)?;
        Ok(out.pop().expect("one request, one answer"))
    }

    /// Run a batch of requests to completion, pipelined, retrying across
    /// BUSY frames, retryable errors (BACKEND, DEADLINE), and broken
    /// connections. Ids must be unique within the call. Returns outputs
    /// in request order.
    ///
    /// Fatal server verdicts (BAD_REQUEST, PROTOCOL, SHUTTING_DOWN) abort
    /// the whole call — retrying can't fix a malformed request, and a
    /// draining server has said it won't take new work.
    pub fn infer_many(
        &mut self,
        reqs: &[(u64, Vec<f32>)],
        deadline_ms: u32,
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let started = Instant::now();
        let mut answers: HashMap<u64, Vec<f32>> = HashMap::with_capacity(reqs.len());
        let mut attempt = 0usize;
        while answers.len() < reqs.len() {
            if attempt >= self.policy.max_attempts {
                anyhow::bail!(
                    "gave up after {attempt} attempts with {} of {} unanswered",
                    reqs.len() - answers.len(),
                    reqs.len()
                );
            }
            if let Some(budget) = self.policy.budget {
                if started.elapsed() >= budget {
                    anyhow::bail!(
                        "retry budget {budget:?} exhausted with {} of {} unanswered",
                        reqs.len() - answers.len(),
                        reqs.len()
                    );
                }
            }
            if attempt > 0 {
                self.retried += (reqs.len() - answers.len()) as u64;
            }
            let hint_ms = match self.round(reqs, deadline_ms, &mut answers)? {
                RoundOutcome::Progress { hint_ms } => hint_ms,
                RoundOutcome::ConnLost => 0,
            };
            attempt += 1;
            if answers.len() < reqs.len() {
                // Honor the server's retry-after hint when it exceeds our
                // own backoff — the queue knows its drain rate better
                // than an exponential curve does.
                let backoff = self.policy.backoff(attempt - 1, &mut self.rng);
                std::thread::sleep(backoff.max(Duration::from_millis(u64::from(hint_ms))));
            }
        }
        Ok(reqs.iter().map(|(id, _)| answers.remove(id).expect("answered")).collect())
    }

    /// One round: (re)connect if needed, send every unanswered request,
    /// receive exactly as many replies as sends succeeded. `Err` only on
    /// fatal verdicts.
    fn round(
        &mut self,
        reqs: &[(u64, Vec<f32>)],
        deadline_ms: u32,
        answers: &mut HashMap<u64, Vec<f32>>,
    ) -> anyhow::Result<RoundOutcome> {
        if self.conn.is_none() {
            match (self.connect)() {
                Ok(c) => {
                    if self.connected_once {
                        self.reconnects += 1;
                    }
                    self.connected_once = true;
                    self.conn = Some(c);
                }
                // Server not reachable right now: back off and redial.
                Err(_) => return Ok(RoundOutcome::ConnLost),
            }
        }
        let conn = self.conn.as_mut().expect("just connected");

        let mut sent = 0usize;
        for (id, input) in reqs.iter().filter(|(id, _)| !answers.contains_key(id)) {
            match conn.send_infer(*id, input, deadline_ms) {
                Ok(()) => sent += 1,
                Err(_) => {
                    // Mid-round send failure: replies for what was sent die
                    // with the connection; resend everything next round.
                    self.conn = None;
                    return Ok(RoundOutcome::ConnLost);
                }
            }
        }

        let mut hint_ms = 0u32;
        for _ in 0..sent {
            let f = match conn.recv() {
                Ok(f) => f,
                Err(_) => {
                    // Damaged or dead wire (CRC mismatch included): the
                    // stream position is unrecoverable — reconnect.
                    self.conn = None;
                    return Ok(RoundOutcome::ConnLost);
                }
            };
            match f.kind {
                FrameKind::Result => {
                    if reqs.iter().any(|(id, _)| *id == f.id) {
                        answers.insert(f.id, payload_f32(&f.payload)?);
                    }
                }
                FrameKind::Busy => hint_ms = hint_ms.max(f.aux),
                FrameKind::Error => match f.aux {
                    // Transient: the batch failed or the deadline expired
                    // in queue — a retry goes to a fresh batch.
                    err_code::BACKEND | err_code::DEADLINE => {}
                    // The server answers PROTOCOL under id 0 and closes
                    // when a frame is damaged in flight. This client only
                    // sends well-formed frames, so that verdict means wire
                    // corruption, not a bad request: reconnect and resend.
                    err_code::PROTOCOL => {
                        self.conn = None;
                        return Ok(RoundOutcome::ConnLost);
                    }
                    code => anyhow::bail!(
                        "fatal server error {} on request {}: {}",
                        error_name(code),
                        f.id,
                        String::from_utf8_lossy(&f.payload)
                    ),
                },
                other => anyhow::bail!("unexpected reply kind {other:?} to INFER"),
            }
        }
        Ok(RoundOutcome::Progress { hint_ms })
    }
}
