//! Blocking wire-protocol client — the test harness's and CLI's view of
//! the server. Deliberately symmetric with the server reader: header
//! first, declared length capped before allocation, CRC checked, and only
//! server→client frame kinds accepted.

use super::frame::{
    err_code, frame_crc, parse_header, payload_f32, Frame, FrameKind, CRC_OFFSET,
    DEFAULT_MAX_PAYLOAD, HEADER_LEN,
};
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// One blocking connection to a [`TcpFrontend`](super::TcpFrontend).
pub struct WireClient {
    stream: TcpStream,
    max_payload: usize,
}

impl WireClient {
    /// Connect with a 30 s read timeout (a wedged server surfaces as an
    /// `Err`, not a hang).
    pub fn connect(addr: impl ToSocketAddrs) -> anyhow::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        Ok(Self { stream, max_payload: DEFAULT_MAX_PAYLOAD })
    }

    /// Send any frame (pipelining: responses arrive via [`recv`](Self::recv)
    /// in server completion order, matched by id).
    pub fn send(&mut self, frame: &Frame) -> anyhow::Result<()> {
        self.stream.write_all(&frame.encode())?;
        Ok(())
    }

    /// Send one INFER frame without waiting for its response.
    pub fn send_infer(&mut self, id: u64, input: &[f32], deadline_ms: u32) -> anyhow::Result<()> {
        self.send(&Frame::infer(id, input, deadline_ms))
    }

    /// Receive the next server frame (CRC-checked; only server→client
    /// kinds accepted).
    pub fn recv(&mut self) -> anyhow::Result<Frame> {
        let mut header = [0u8; HEADER_LEN];
        self.stream.read_exact(&mut header)?;
        let h = parse_header(&header, self.max_payload)?;
        let mut payload = vec![0u8; h.len];
        self.stream.read_exact(&mut payload)?;
        let got = frame_crc(&header[..CRC_OFFSET], &payload);
        if got != h.crc {
            anyhow::bail!("response frame CRC mismatch (expected {:08x}, got {got:08x})", h.crc);
        }
        match h.kind {
            FrameKind::Result
            | FrameKind::Error
            | FrameKind::Busy
            | FrameKind::StatsText
            | FrameKind::ShutdownAck => {}
            other => anyhow::bail!("unexpected server frame kind {other:?}"),
        }
        Ok(Frame { kind: h.kind, id: h.id, aux: h.aux, payload })
    }

    /// Blocking single request: send INFER, wait for its frame, return the
    /// output column. BUSY, ERROR, and id mismatches are `Err`.
    pub fn infer(&mut self, id: u64, input: &[f32], deadline_ms: u32) -> anyhow::Result<Vec<f32>> {
        self.send_infer(id, input, deadline_ms)?;
        let f = self.recv()?;
        match f.kind {
            FrameKind::Result => {
                if f.id != id {
                    anyhow::bail!("response id {} for request id {id}", f.id);
                }
                Ok(payload_f32(&f.payload)?)
            }
            FrameKind::Busy => anyhow::bail!("server busy (admission control rejected {id})"),
            FrameKind::Error => anyhow::bail!(
                "server error {} on request {}: {}",
                error_name(f.aux),
                f.id,
                String::from_utf8_lossy(&f.payload)
            ),
            other => anyhow::bail!("unexpected reply kind {other:?} to INFER"),
        }
    }

    /// Fetch the Prometheus-style metrics text.
    pub fn stats_text(&mut self) -> anyhow::Result<String> {
        self.send(&Frame::stats(0))?;
        let f = self.recv()?;
        match f.kind {
            FrameKind::StatsText => Ok(String::from_utf8_lossy(&f.payload).into_owned()),
            other => anyhow::bail!("unexpected reply kind {other:?} to STATS"),
        }
    }

    /// Ask the server to shut down gracefully; waits for the ack.
    pub fn shutdown_server(&mut self) -> anyhow::Result<()> {
        self.send(&Frame::shutdown(0))?;
        let f = self.recv()?;
        match f.kind {
            FrameKind::ShutdownAck => Ok(()),
            other => anyhow::bail!("unexpected reply kind {other:?} to SHUTDOWN"),
        }
    }
}

/// Human-readable name for an ERROR frame's aux code.
pub fn error_name(code: u32) -> &'static str {
    match code {
        err_code::PROTOCOL => "PROTOCOL",
        err_code::BAD_REQUEST => "BAD_REQUEST",
        err_code::BACKEND => "BACKEND",
        err_code::DEADLINE => "DEADLINE",
        err_code::SHUTTING_DOWN => "SHUTTING_DOWN",
        _ => "UNKNOWN",
    }
}
