//! TCP serving front-end: the network face of the batched inference
//! server.
//!
//! Three pieces, each its own module:
//!
//! - [`frame`]: the LB2 wire protocol — length-prefixed, CRC-checked
//!   binary frames mirroring the `.lb2` artifact framing discipline.
//!   Decoding is a pure function over untrusted bytes (the adversarial
//!   harness exercises every truncation and bit flip without a socket).
//! - [`server`]: [`TcpFrontend`] — a std::net accept loop with
//!   per-connection reader/writer threads feeding the cross-connection
//!   dynamic batcher ([`crate::coordinator::InferenceServer`]), with
//!   admission control (BUSY), per-request deadlines, a slow-loris frame
//!   timer, and graceful drain-on-shutdown.
//! - [`client`]: [`WireClient`] — the blocking client used by the CLI's
//!   `client` subcommand, the examples, and the test suites — and
//!   [`RetryingClient`], its self-healing wrapper (reconnect, resend by
//!   id, exponential backoff with seeded jitter, BUSY retry-after hints).

pub mod client;
pub mod frame;
pub mod server;

pub use client::{error_name, RetryPolicy, RetryingClient, WireClient};
pub use frame::{
    err_code, f32_payload, payload_f32, Frame, FrameKind, WireError, DEFAULT_MAX_PAYLOAD,
    HEADER_LEN, WIRE_MAGIC, WIRE_VERSION,
};
pub use server::{ServingConfig, TcpFrontend};
