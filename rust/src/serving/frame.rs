//! The LB2 wire protocol: length-prefixed, CRC-checked binary frames.
//!
//! Mirrors the `.lb2` artifact framing discipline ([`crate::artifact`]):
//! magic + version up front, an explicit declared length that is bounded
//! **before** any allocation, and an IEEE CRC32 over everything else so a
//! single flipped bit anywhere in a frame is detected rather than decoded
//! into a wrong-id or wrong-payload response. Decoding is a pure function
//! over bytes (`decode`) so the adversarial harness can exercise every
//! truncation and bit flip without a socket.
//!
//! ## Byte layout (little-endian, 28-byte header)
//!
//! | offset | size | field                                  |
//! |--------|------|----------------------------------------|
//! | 0      | 4    | magic `0x89 'L' 'B' 'W'`               |
//! | 4      | 2    | protocol version (= 1)                 |
//! | 6      | 2    | frame kind ([`FrameKind`])             |
//! | 8      | 8    | request id                             |
//! | 16     | 4    | aux (kind-specific, see below)         |
//! | 20     | 4    | payload length in bytes                |
//! | 24     | 4    | CRC32 over header\[0..24\] ++ payload  |
//! | 28     | len  | payload                                |
//!
//! `aux` carries the deadline in ms on INFER (0 = server default), the
//! executed batch size on RESULT, the error code on ERROR, a retry-after
//! hint in ms on BUSY (0 = no hint), and the health code on
//! HEALTH_REPORT. Payloads are raw little-endian f32s on INFER/RESULT,
//! UTF-8 text on ERROR/STATS_TEXT/HEALTH_REPORT, and empty elsewhere.
//!
//! The CLUSTER kinds (JOIN/ASSIGN/ACT/PART/HEARTBEAT, codes 11–15; see
//! [`crate::cluster`]) ride the identical framing at the same protocol
//! version: aux is the plan epoch on ASSIGN and HEARTBEAT, the
//! epoch-stamped layer index on ACT (`(epoch & 0xFFFF) << 16 | layer`,
//! packed by [`crate::cluster::act_aux`]; layer = 0 in pipeline mode),
//! and the shard index on PART. JOIN's payload is the peer's serve
//! address as ASCII, ASSIGN's the encoded shard assignment, ACT/PART's
//! raw little-endian f32s.

use crate::artifact::{crc_finish, crc_update, CRC_INIT};

/// Wire magic: like the artifact's `\x89LB2`, the high bit up front
/// catches 7-bit-stripping transports; `W` marks the wire protocol.
pub const WIRE_MAGIC: [u8; 4] = [0x89, b'L', b'B', b'W'];

/// Protocol version carried by every frame.
pub const WIRE_VERSION: u16 = 1;

/// Fixed header size in bytes (payload follows).
pub const HEADER_LEN: usize = 28;

/// Byte offset of the CRC field inside the header: the CRC covers
/// `header[0..CRC_OFFSET] ++ payload`.
pub const CRC_OFFSET: usize = 24;

/// Default cap on declared payload length — enforced before allocation,
/// so a hostile 4 GiB length field cannot balloon memory.
pub const DEFAULT_MAX_PAYLOAD: usize = 1 << 20;

/// Frame kinds. Requests flow client → server (INFER, STATS, SHUTDOWN),
/// the rest flow server → client.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum FrameKind {
    /// Client → server: run one forward pass on the f32 payload.
    Infer = 1,
    /// Server → client: the f32 output column; aux = executed batch size.
    Result = 2,
    /// Server → client: request failed; aux = [`err_code`], payload = text.
    Error = 3,
    /// Server → client: admission control rejected the request (queue full).
    Busy = 4,
    /// Client → server: request a metrics snapshot.
    Stats = 5,
    /// Server → client: Prometheus-style text exposition payload.
    StatsText = 6,
    /// Client → server: ask the server to shut down gracefully.
    Shutdown = 7,
    /// Server → client: shutdown acknowledged; in-flight work will drain.
    ShutdownAck = 8,
    /// Client → server: request a health probe.
    Health = 9,
    /// Server → client: health state; aux = [`crate::coordinator::HealthState`]
    /// code (0 healthy / 1 degraded / 2 draining), payload = state name.
    HealthReport = 10,
    /// Peer → tracker (CLUSTER): register for shard assignment; payload =
    /// the peer's serve address as ASCII (`host:port`).
    Join = 11,
    /// Tracker → peer (CLUSTER): shard assignment; aux = plan epoch,
    /// payload = the encoded [`crate::cluster::Assignment`].
    Assign = 12,
    /// CLUSTER activation frame: an f32 activation column entering a
    /// pipeline stage (or, in row-shard mode, a layer input broadcast to
    /// every shard). aux packs the plan epoch and the layer index
    /// ([`crate::cluster::act_aux`]) so a stale stage rejects it.
    Act = 13,
    /// Peer → tracker (CLUSTER, row-shard mode): one shard's slice of a
    /// layer output; aux = shard index.
    Part = 14,
    /// Peer → tracker (CLUSTER): liveness beacon on the registration
    /// connection; aux = the epoch the peer is serving.
    Heartbeat = 15,
}

impl FrameKind {
    pub fn from_u16(v: u16) -> Option<Self> {
        Some(match v {
            1 => FrameKind::Infer,
            2 => FrameKind::Result,
            3 => FrameKind::Error,
            4 => FrameKind::Busy,
            5 => FrameKind::Stats,
            6 => FrameKind::StatsText,
            7 => FrameKind::Shutdown,
            8 => FrameKind::ShutdownAck,
            9 => FrameKind::Health,
            10 => FrameKind::HealthReport,
            11 => FrameKind::Join,
            12 => FrameKind::Assign,
            13 => FrameKind::Act,
            14 => FrameKind::Part,
            15 => FrameKind::Heartbeat,
            _ => return None,
        })
    }
}

/// ERROR-frame `aux` codes.
pub mod err_code {
    /// Malformed frame (bad magic/version/kind/CRC/length).
    pub const PROTOCOL: u32 = 1;
    /// Frame was well-formed but the request is invalid (e.g. payload not
    /// a whole number of f32s, wrong input width).
    pub const BAD_REQUEST: u32 = 2;
    /// The backend failed the request's batch (panic or wrong shape).
    pub const BACKEND: u32 = 3;
    /// The request's queue-time deadline passed before execution.
    pub const DEADLINE: u32 = 4;
    /// The server is shutting down and no longer admits requests.
    pub const SHUTTING_DOWN: u32 = 5;
}

/// Decoding/encoding failure — always an `Err`, never a panic: this enum
/// is the complete list of ways untrusted bytes can be wrong.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Fewer bytes than the header (or declared payload) requires.
    Truncated { need: usize, have: usize },
    BadMagic([u8; 4]),
    BadVersion(u16),
    BadKind(u16),
    /// Declared payload length exceeds the negotiated cap.
    Oversize { declared: usize, max: usize },
    /// CRC mismatch: the frame was damaged in flight.
    BadCrc { expect: u32, got: u32 },
    /// Payload malformed for its kind (e.g. not a multiple of 4 bytes).
    BadPayload(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::BadMagic(m) => write!(f, "bad wire magic {m:02x?}"),
            WireError::BadVersion(v) => {
                write!(f, "unsupported wire version {v} (expected {WIRE_VERSION})")
            }
            WireError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::Oversize { declared, max } => {
                write!(f, "declared payload {declared} exceeds cap {max}")
            }
            WireError::BadCrc { expect, got } => {
                write!(f, "frame CRC mismatch: expected {expect:08x}, got {got:08x}")
            }
            WireError::BadPayload(msg) => write!(f, "bad payload: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Parsed header fields, pre-CRC-check.
pub struct Header {
    pub kind: FrameKind,
    pub id: u64,
    pub aux: u32,
    pub len: usize,
    pub crc: u32,
}

/// Parse and validate a 28-byte header: magic, version, kind, and the
/// declared-length cap are all checked **here**, before the caller reads
/// or allocates a payload.
pub fn parse_header(buf: &[u8; HEADER_LEN], max_payload: usize) -> Result<Header, WireError> {
    if buf[0..4] != WIRE_MAGIC {
        return Err(WireError::BadMagic([buf[0], buf[1], buf[2], buf[3]]));
    }
    let version = u16::from_le_bytes([buf[4], buf[5]]);
    if version != WIRE_VERSION {
        return Err(WireError::BadVersion(version));
    }
    let kind_raw = u16::from_le_bytes([buf[6], buf[7]]);
    let kind = FrameKind::from_u16(kind_raw).ok_or(WireError::BadKind(kind_raw))?;
    let id = u64::from_le_bytes(buf[8..16].try_into().expect("8 bytes"));
    let aux = u32::from_le_bytes(buf[16..20].try_into().expect("4 bytes"));
    let len = u32::from_le_bytes(buf[20..24].try_into().expect("4 bytes")) as usize;
    if len > max_payload {
        return Err(WireError::Oversize { declared: len, max: max_payload });
    }
    let crc = u32::from_le_bytes(buf[24..28].try_into().expect("4 bytes"));
    Ok(Header { kind, id, aux, len, crc })
}

/// CRC32 over the pre-CRC header prefix and the payload.
pub fn frame_crc(header_prefix: &[u8], payload: &[u8]) -> u32 {
    crc_finish(crc_update(crc_update(CRC_INIT, header_prefix), payload))
}

/// One wire frame, fully decoded.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub id: u64,
    pub aux: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    /// INFER request: `deadline_ms` rides aux (0 = server default).
    pub fn infer(id: u64, input: &[f32], deadline_ms: u32) -> Self {
        Self { kind: FrameKind::Infer, id, aux: deadline_ms, payload: f32_payload(input) }
    }

    /// RESULT response: aux carries the executed batch size.
    pub fn result(id: u64, output: &[f32], batch_size: u32) -> Self {
        Self { kind: FrameKind::Result, id, aux: batch_size, payload: f32_payload(output) }
    }

    /// ERROR response: aux carries an [`err_code`], payload a UTF-8 message.
    pub fn error(id: u64, code: u32, msg: &str) -> Self {
        Self { kind: FrameKind::Error, id, aux: code, payload: msg.as_bytes().to_vec() }
    }

    /// BUSY response: admission control rejected the request. `aux`
    /// carries a retry-after hint in milliseconds (0 = no hint) that
    /// [`RetryPolicy`](crate::serving::RetryPolicy)-driven clients honor
    /// before resending.
    pub fn busy(id: u64, retry_after_ms: u32) -> Self {
        Self { kind: FrameKind::Busy, id, aux: retry_after_ms, payload: Vec::new() }
    }

    /// HEALTH probe request.
    pub fn health(id: u64) -> Self {
        Self { kind: FrameKind::Health, id, aux: 0, payload: Vec::new() }
    }

    /// HEALTH_REPORT response: aux carries the numeric health code,
    /// payload the human-readable state name.
    pub fn health_report(id: u64, code: u32, name: &str) -> Self {
        Self { kind: FrameKind::HealthReport, id, aux: code, payload: name.as_bytes().to_vec() }
    }

    /// STATS request.
    pub fn stats(id: u64) -> Self {
        Self { kind: FrameKind::Stats, id, aux: 0, payload: Vec::new() }
    }

    /// STATS_TEXT response carrying the metrics exposition text.
    pub fn stats_text(id: u64, text: &str) -> Self {
        Self { kind: FrameKind::StatsText, id, aux: 0, payload: text.as_bytes().to_vec() }
    }

    /// SHUTDOWN request.
    pub fn shutdown(id: u64) -> Self {
        Self { kind: FrameKind::Shutdown, id, aux: 0, payload: Vec::new() }
    }

    /// SHUTDOWN_ACK response.
    pub fn shutdown_ack(id: u64) -> Self {
        Self { kind: FrameKind::ShutdownAck, id, aux: 0, payload: Vec::new() }
    }

    /// CLUSTER JOIN: a peer registering its serve address with the tracker.
    pub fn join(id: u64, serve_addr: &str) -> Self {
        Self { kind: FrameKind::Join, id, aux: 0, payload: serve_addr.as_bytes().to_vec() }
    }

    /// CLUSTER ASSIGN: an encoded shard assignment; aux = plan epoch.
    pub fn assign(id: u64, epoch: u32, plan: Vec<u8>) -> Self {
        Self { kind: FrameKind::Assign, id, aux: epoch, payload: plan }
    }

    /// CLUSTER ACT: an f32 activation column; `aux` packs the plan epoch
    /// and layer index — build it with [`crate::cluster::act_aux`].
    pub fn act(id: u64, aux: u32, x: &[f32]) -> Self {
        Self { kind: FrameKind::Act, id, aux, payload: f32_payload(x) }
    }

    /// CLUSTER PART: one shard's f32 output slice; aux = shard index.
    pub fn part(id: u64, shard: u32, y: &[f32]) -> Self {
        Self { kind: FrameKind::Part, id, aux: shard, payload: f32_payload(y) }
    }

    /// CLUSTER HEARTBEAT: liveness beacon; aux = the epoch being served.
    pub fn heartbeat(id: u64, epoch: u32) -> Self {
        Self { kind: FrameKind::Heartbeat, id, aux: epoch, payload: Vec::new() }
    }

    /// Serialize to header ++ payload with the CRC filled in.
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(HEADER_LEN + self.payload.len());
        buf.extend_from_slice(&WIRE_MAGIC);
        buf.extend_from_slice(&WIRE_VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.kind as u16).to_le_bytes());
        buf.extend_from_slice(&self.id.to_le_bytes());
        buf.extend_from_slice(&self.aux.to_le_bytes());
        buf.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        let crc = frame_crc(&buf[..CRC_OFFSET], &self.payload);
        buf.extend_from_slice(&crc.to_le_bytes());
        buf.extend_from_slice(&self.payload);
        buf
    }

    /// Decode one frame from the front of `buf`; returns the frame and how
    /// many bytes it consumed. Pure function over untrusted bytes: every
    /// failure is a typed `Err`, the declared length is capped before the
    /// payload is copied, and the CRC must match.
    pub fn decode(buf: &[u8], max_payload: usize) -> Result<(Frame, usize), WireError> {
        if buf.len() < HEADER_LEN {
            return Err(WireError::Truncated { need: HEADER_LEN, have: buf.len() });
        }
        let header: &[u8; HEADER_LEN] = buf[..HEADER_LEN].try_into().expect("checked");
        let h = parse_header(header, max_payload)?;
        let total = HEADER_LEN + h.len;
        if buf.len() < total {
            return Err(WireError::Truncated { need: total, have: buf.len() });
        }
        let payload = &buf[HEADER_LEN..total];
        let got = frame_crc(&buf[..CRC_OFFSET], payload);
        if got != h.crc {
            return Err(WireError::BadCrc { expect: h.crc, got });
        }
        Ok((Frame { kind: h.kind, id: h.id, aux: h.aux, payload: payload.to_vec() }, total))
    }
}

/// Little-endian f32 slice → payload bytes.
pub fn f32_payload(values: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(values.len() * 4);
    for v in values {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Payload bytes → f32s; `Err` when not a whole number of f32s.
pub fn payload_f32(payload: &[u8]) -> Result<Vec<f32>, WireError> {
    if payload.len() % 4 != 0 {
        return Err(WireError::BadPayload(format!(
            "f32 payload length {} not a multiple of 4",
            payload.len()
        )));
    }
    Ok(payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The header layout is a wire contract: pin every byte offset so a
    /// refactor cannot silently renumber fields.
    #[test]
    fn header_byte_layout_is_pinned() {
        let f = Frame::infer(0x1122_3344_5566_7788, &[1.0], 0xAABB_CCDD);
        let bytes = f.encode();
        assert_eq!(bytes.len(), HEADER_LEN + 4);
        assert_eq!(&bytes[0..4], &WIRE_MAGIC);
        assert_eq!(u16::from_le_bytes([bytes[4], bytes[5]]), WIRE_VERSION);
        assert_eq!(u16::from_le_bytes([bytes[6], bytes[7]]), FrameKind::Infer as u16);
        assert_eq!(
            u64::from_le_bytes(bytes[8..16].try_into().unwrap()),
            0x1122_3344_5566_7788
        );
        assert_eq!(u32::from_le_bytes(bytes[16..20].try_into().unwrap()), 0xAABB_CCDD);
        assert_eq!(u32::from_le_bytes(bytes[20..24].try_into().unwrap()), 4);
        assert_eq!(&bytes[HEADER_LEN..], &1.0f32.to_le_bytes());
    }

    #[test]
    fn roundtrip_every_kind() {
        let frames = [
            Frame::infer(1, &[1.5, -2.5], 30),
            Frame::result(2, &[0.25], 8),
            Frame::error(3, err_code::BACKEND, "boom"),
            Frame::busy(4, 25),
            Frame::stats(5),
            Frame::stats_text(6, "lb2_queue_depth 0\n"),
            Frame::shutdown(7),
            Frame::shutdown_ack(8),
            Frame::health(9),
            Frame::health_report(10, 1, "degraded"),
            Frame::join(11, "127.0.0.1:41600"),
            Frame::assign(12, 3, vec![1, 2, 3, 4]),
            Frame::act(13, 1, &[0.5, -0.5]),
            Frame::part(14, 2, &[9.75]),
            Frame::heartbeat(15, 3),
        ];
        for f in frames {
            let bytes = f.encode();
            let (back, used) = Frame::decode(&bytes, DEFAULT_MAX_PAYLOAD).unwrap();
            assert_eq!(used, bytes.len());
            assert_eq!(back, f);
        }
    }

    #[test]
    fn oversize_declared_length_rejected_before_payload() {
        let mut bytes = Frame::infer(1, &[1.0; 8], 0).encode();
        bytes[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        // Only the header is present — decode must reject on the declared
        // length, not try to read (or allocate) 4 GiB.
        let err = Frame::decode(&bytes[..HEADER_LEN], DEFAULT_MAX_PAYLOAD).unwrap_err();
        assert!(matches!(err, WireError::Oversize { .. }), "{err:?}");
    }

    #[test]
    fn f32_payload_roundtrip_and_ragged_rejection() {
        let vals = [1.0f32, -0.5, f32::MIN_POSITIVE, 3.25e7];
        assert_eq!(payload_f32(&f32_payload(&vals)).unwrap(), vals);
        assert!(matches!(payload_f32(&[0u8; 5]), Err(WireError::BadPayload(_))));
    }

    #[test]
    fn bad_magic_version_kind_crc_all_rejected() {
        let good = Frame::busy(9, 0).encode();
        let mut m = good.clone();
        m[0] = b'X';
        assert!(matches!(
            Frame::decode(&m, DEFAULT_MAX_PAYLOAD),
            Err(WireError::BadMagic(_))
        ));
        let mut v = good.clone();
        v[4] = 0xFF;
        assert!(matches!(
            Frame::decode(&v, DEFAULT_MAX_PAYLOAD),
            Err(WireError::BadVersion(_))
        ));
        let mut k = good.clone();
        k[6] = 0xEE;
        assert!(matches!(
            Frame::decode(&k, DEFAULT_MAX_PAYLOAD),
            Err(WireError::BadKind(_))
        ));
        let mut c = good;
        c[CRC_OFFSET] ^= 0x01;
        assert!(matches!(
            Frame::decode(&c, DEFAULT_MAX_PAYLOAD),
            Err(WireError::BadCrc { .. })
        ));
    }
}
