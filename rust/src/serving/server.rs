//! The TCP front-end: std::net sockets in front of the cross-connection
//! dynamic batcher ([`InferenceServer`]).
//!
//! One accept loop hands each connection a reader thread and a writer
//! thread. Readers parse frames incrementally (header first — so the
//! declared length is capped before any payload allocation), validate the
//! CRC, and submit INFER requests through a non-blocking [`SubmitHandle`];
//! admission control answers BUSY instead of queueing unboundedly. Every
//! request of a connection carries the same [`ConnSink`] funnel, so worker
//! completions from **any** batch serialize onto that connection's writer
//! thread — requests from many connections coalesce into one
//! `forward_batch_into` call, and their responses fan back out without a
//! per-request channel.
//!
//! ## Batcher state machine (per worker, inherited from the coordinator)
//!
//! ```text
//!        ┌──────────── idle: block on queue ◄───────────────┐
//!        ▼                                                  │
//!   first request ──► gather: recv_timeout until            │
//!                     max_batch OR max_wait ──► expire:     │
//!                     drop requests past deadline ──► run:  │
//!                     ONE feature-major batch ──► complete ─┘
//! ```
//!
//! ## Shutdown sequencing
//!
//! `shutdown()` sets the flag, joins the accept loop (which joins every
//! connection: readers observe the flag at their next poll tick and drop
//! their side of the writer funnel — the writer keeps draining until every
//! in-flight request's sink has fired, because the inner workers are still
//! alive at this point), and only then drains and joins the inner server.
//! Accepted requests are therefore answered, not lost.

use super::frame::{
    err_code, frame_crc, parse_header, payload_f32, Frame, FrameKind, CRC_OFFSET,
    DEFAULT_MAX_PAYLOAD, HEADER_LEN,
};
use crate::coordinator::{
    BatchBackend, HealthState, InferenceServer, ReplySink, RequestOutcome, ServerConfig,
    ServerStats, SubmitHandle, TrySubmitError,
};
use crate::faults::{FaultPlan, FaultyStream};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// TCP front-end configuration; `batch` is the inner dynamic batcher's.
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// Cap on a frame's declared payload length (checked pre-allocation).
    pub max_payload: usize,
    /// Slow-loris guard: once a frame's first byte arrives, the rest must
    /// follow within this window or the connection is closed. Idle
    /// connections (no partial frame) never time out.
    pub frame_timeout: Duration,
    /// Poll interval for the nonblocking accept loop and reader shutdown
    /// checks.
    pub poll: Duration,
    /// Deadline applied to INFER frames that carry `deadline_ms = 0`.
    pub default_deadline: Option<Duration>,
    /// Bound of each connection's outbound frame funnel.
    pub outbound_depth: usize,
    /// When set, INFER inputs of any other width are rejected as
    /// BAD_REQUEST before touching the queue (serving a model of known
    /// `d_in`).
    pub expect_width: Option<usize>,
    /// Seeded wire-fault injection for chaos testing: when set, every
    /// accepted connection's read half is wrapped in a
    /// [`FaultyStream`] over `plan.stream_injector(2·conn)` and its write
    /// half over `2·conn + 1`. `None` (the default) keeps connections on
    /// bare `TcpStream`s — the production path pays nothing.
    pub faults: Option<Arc<FaultPlan>>,
    /// Model weight bytes on this process's heap, reported through the
    /// STATS frame as `lb2_model_resident_bytes` (the CLI sets this from
    /// the loaded stack; 0 when unknown). Disjoint from
    /// [`model_mapped_bytes`](Self::model_mapped_bytes).
    pub model_resident_bytes: u64,
    /// Model weight bytes served from a page-cache `.lb2` mapping,
    /// reported as `lb2_model_mapped_bytes` (0 for eager loads).
    pub model_mapped_bytes: u64,
    /// Inner batcher configuration (batch size, wait, queue bound, workers).
    pub batch: ServerConfig,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            max_payload: DEFAULT_MAX_PAYLOAD,
            frame_timeout: Duration::from_secs(10),
            poll: Duration::from_millis(25),
            default_deadline: None,
            outbound_depth: 1024,
            expect_width: None,
            faults: None,
            model_resident_bytes: 0,
            model_mapped_bytes: 0,
            batch: ServerConfig::default(),
        }
    }
}

/// A running TCP serving front-end. Dropping it shuts down gracefully.
pub struct TcpFrontend {
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    inner: Option<InferenceServer>,
}

impl TcpFrontend {
    /// Bind `listen` and start serving. `factory(worker_index)` builds one
    /// [`BatchBackend`] per inner worker (same contract as
    /// [`InferenceServer::start_pool`]).
    pub fn start<B: BatchBackend>(
        listen: impl ToSocketAddrs,
        cfg: ServingConfig,
        factory: impl FnMut(usize) -> B,
    ) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(listen)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let inner = InferenceServer::start_pool(cfg.batch.clone(), factory);
        let handle = inner.handle();
        let shutdown = Arc::new(AtomicBool::new(false));
        let accept = {
            let shutdown = Arc::clone(&shutdown);
            std::thread::spawn(move || accept_loop(&listener, &cfg, &handle, &shutdown))
        };
        Ok(Self { local_addr, shutdown, accept: Some(accept), inner: Some(inner) })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// True once shutdown has been requested (locally or by a SHUTDOWN
    /// frame from a client).
    pub fn is_shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Request shutdown without blocking (the accept loop and connections
    /// wind down on their next poll tick; call [`shutdown`](Self::shutdown)
    /// to join them). Health flips to Draining immediately.
    pub fn trigger_shutdown(&self) {
        if let Some(inner) = self.inner.as_ref() {
            inner.begin_drain();
        }
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Current health of the inner batcher.
    pub fn health(&self) -> HealthState {
        self.inner.as_ref().expect("frontend running").health()
    }

    /// Snapshot the inner batcher's statistics.
    pub fn stats(&self) -> ServerStats {
        self.inner.as_ref().expect("frontend running").stats()
    }

    /// Graceful shutdown: stop accepting, drain every connection's
    /// in-flight work, then drain and join the inner batcher. Returns the
    /// final statistics.
    pub fn shutdown(mut self) -> ServerStats {
        self.halt().expect("first shutdown call")
    }

    fn halt(&mut self) -> Option<ServerStats> {
        if let Some(inner) = self.inner.as_ref() {
            inner.begin_drain();
        }
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // Only after every connection thread has been joined (no live
        // SubmitHandle clones, no in-flight sinks) is it safe to drain and
        // join the workers.
        self.inner.take().map(InferenceServer::shutdown)
    }
}

impl Drop for TcpFrontend {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Nonblocking accept loop: spawns one connection handler per accept,
/// reaps finished handlers on every pass, joins all of them on shutdown
/// (which is what makes [`TcpFrontend::halt`]'s drain ordering sound).
fn accept_loop(
    listener: &TcpListener,
    cfg: &ServingConfig,
    handle: &SubmitHandle,
    shutdown: &Arc<AtomicBool>,
) {
    let conns = Arc::new(AtomicUsize::new(0));
    let mut children: Vec<JoinHandle<()>> = Vec::new();
    // Monotone connection counter: the fault plan's per-connection stream
    // index, so a connection's injected fault schedule depends only on its
    // accept ordinal, never on how long earlier connections lived.
    let mut conn_idx: u64 = 0;
    while !shutdown.load(Ordering::SeqCst) {
        // Reap every pass (allocation-free swap_remove scan): a long-lived
        // low-concurrency server must not pin dead handlers' stacks until
        // some high-water mark is reached.
        let mut i = 0;
        while i < children.len() {
            if children[i].is_finished() {
                let _ = children.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let cfg = cfg.clone();
                let handle = handle.clone();
                let shutdown = Arc::clone(shutdown);
                let conns = Arc::clone(&conns);
                let idx = conn_idx;
                conn_idx += 1;
                conns.fetch_add(1, Ordering::SeqCst);
                children.push(std::thread::spawn(move || {
                    if let Err(e) = connection(stream, &cfg, &handle, &shutdown, &conns, idx) {
                        eprintln!("serving: connection setup failed: {e}");
                    }
                    conns.fetch_sub(1, Ordering::SeqCst);
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(cfg.poll);
            }
            Err(e) => {
                eprintln!("serving: accept failed: {e}");
                std::thread::sleep(cfg.poll);
            }
        }
    }
    for c in children {
        let _ = c.join();
    }
}

/// The writer thread's stream: a plain sink plus the ability to shut the
/// underlying socket down once the funnel drains. Implemented for bare
/// `TcpStream` (production) and the fault-injected wrapper (chaos), which
/// is how the no-fault path stays monomorphized over plain sockets with
/// zero added work per frame.
trait WriteHalf: Write + Send + 'static {
    fn shutdown_conn(&self);
}

impl WriteHalf for TcpStream {
    fn shutdown_conn(&self) {
        let _ = self.shutdown(std::net::Shutdown::Both);
    }
}

impl WriteHalf for FaultyStream<TcpStream> {
    fn shutdown_conn(&self) {
        let _ = self.get_ref().shutdown(std::net::Shutdown::Both);
    }
}

/// One connection: reader runs on this thread, writer on its own, joined
/// before return. The writer outlives the reader for as long as in-flight
/// requests hold [`ConnSink`] clones of the funnel sender — that is the
/// mechanism by which accepted work is answered even when the client's
/// reader side has already wound down for shutdown.
///
/// With [`ServingConfig::faults`] set, both halves are wrapped in
/// [`FaultyStream`]s seeded from the connection's accept ordinal `idx`;
/// otherwise the bare `TcpStream` halves are used directly.
fn connection(
    stream: TcpStream,
    cfg: &ServingConfig,
    handle: &SubmitHandle,
    shutdown: &AtomicBool,
    conns: &AtomicUsize,
    idx: u64,
) -> std::io::Result<()> {
    let _ = stream.set_nodelay(true);
    stream.set_read_timeout(Some(cfg.poll))?;
    let write_half = stream.try_clone()?;
    let _ = write_half.set_write_timeout(Some(Duration::from_secs(10)));
    match cfg.faults.as_ref() {
        None => run_connection(stream, write_half, cfg, handle, shutdown, conns),
        Some(plan) => run_connection(
            FaultyStream::new(stream, plan.stream_injector(2 * idx)),
            FaultyStream::new(write_half, plan.stream_injector(2 * idx + 1)),
            cfg,
            handle,
            shutdown,
            conns,
        ),
    }
    Ok(())
}

/// The stream-generic connection body behind [`connection`].
fn run_connection<R: Read, W: WriteHalf>(
    read_half: R,
    write_half: W,
    cfg: &ServingConfig,
    handle: &SubmitHandle,
    shutdown: &AtomicBool,
    conns: &AtomicUsize,
) {
    let (tx, rx) = sync_channel::<Frame>(cfg.outbound_depth);
    let writer = std::thread::spawn(move || writer_loop(write_half, &rx));
    reader_loop(read_half, cfg, handle, shutdown, conns, &tx);
    drop(tx);
    let _ = writer.join();
}

/// Why a polled exact-read stopped.
enum ReadStatus {
    /// Buffer filled.
    Done,
    /// EOF on a frame boundary: the client closed cleanly.
    CleanEof,
    /// Server shutdown was requested.
    Shutdown,
    /// Anything else: mid-frame EOF, frame timeout, socket error.
    Error(String),
}

/// `read_exact` with a poll-interval read timeout so the reader can
/// observe shutdown, plus the slow-loris frame timer: `started` is set at
/// the first byte of a frame and the whole frame must land within
/// `cfg.frame_timeout` of it. A connection idling **between** frames
/// (`started == None`, nothing read) never times out.
fn read_exact_polled<R: Read>(
    stream: &mut R,
    buf: &mut [u8],
    shutdown: &AtomicBool,
    started: &mut Option<Instant>,
    cfg: &ServingConfig,
) -> ReadStatus {
    let mut filled = 0;
    while filled < buf.len() {
        if shutdown.load(Ordering::SeqCst) {
            return ReadStatus::Shutdown;
        }
        if let Some(t0) = *started {
            if t0.elapsed() > cfg.frame_timeout {
                return ReadStatus::Error("frame timeout".into());
            }
        }
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 && started.is_none() {
                    ReadStatus::CleanEof
                } else {
                    ReadStatus::Error("eof mid-frame".into())
                };
            }
            Ok(n) => {
                if started.is_none() {
                    *started = Some(Instant::now());
                }
                filled += n;
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(e) => return ReadStatus::Error(e.to_string()),
        }
    }
    ReadStatus::Done
}

/// Per-connection reader: parse → validate → dispatch, one frame at a
/// time. Malformed *frames* close the connection (the stream position is
/// unrecoverable); malformed *requests* inside valid frames fail only
/// themselves.
fn reader_loop<R: Read>(
    mut stream: R,
    cfg: &ServingConfig,
    handle: &SubmitHandle,
    shutdown: &AtomicBool,
    conns: &AtomicUsize,
    tx: &SyncSender<Frame>,
) {
    let mut header = [0u8; HEADER_LEN];
    loop {
        let mut started: Option<Instant> = None;
        match read_exact_polled(&mut stream, &mut header, shutdown, &mut started, cfg) {
            ReadStatus::Done => {}
            ReadStatus::CleanEof | ReadStatus::Shutdown => return,
            ReadStatus::Error(_) => return,
        }
        let h = match parse_header(&header, cfg.max_payload) {
            Ok(h) => h,
            Err(e) => {
                // id 0: the header is untrusted, including its id field.
                let _ = tx.try_send(Frame::error(0, err_code::PROTOCOL, &e.to_string()));
                return;
            }
        };
        // Length was capped by parse_header, so this allocation is bounded.
        let mut payload = vec![0u8; h.len];
        match read_exact_polled(&mut stream, &mut payload, shutdown, &mut started, cfg) {
            ReadStatus::Done => {}
            ReadStatus::CleanEof | ReadStatus::Shutdown => return,
            ReadStatus::Error(_) => return,
        }
        let got = frame_crc(&header[..CRC_OFFSET], &payload);
        if got != h.crc {
            // Damaged in flight: fields (the id included) are untrusted,
            // so never answer under the frame's id — close instead.
            let _ = tx.try_send(Frame::error(0, err_code::PROTOCOL, "frame CRC mismatch"));
            return;
        }
        match h.kind {
            FrameKind::Infer => {
                let input = match payload_f32(&payload) {
                    Ok(v) => v,
                    Err(e) => {
                        let _ =
                            tx.try_send(Frame::error(h.id, err_code::BAD_REQUEST, &e.to_string()));
                        continue;
                    }
                };
                if let Some(w) = cfg.expect_width {
                    if input.len() != w {
                        let _ = tx.try_send(Frame::error(
                            h.id,
                            err_code::BAD_REQUEST,
                            &format!("input width {} != model d_in {w}", input.len()),
                        ));
                        continue;
                    }
                }
                let deadline = if h.aux > 0 {
                    Some(Instant::now() + Duration::from_millis(u64::from(h.aux)))
                } else {
                    cfg.default_deadline.map(|d| Instant::now() + d)
                };
                let sink = Box::new(ConnSink { tx: tx.clone() });
                match handle.try_submit(h.id, input, deadline, sink) {
                    Ok(()) => {}
                    Err(
                        e @ (TrySubmitError::QueueFull { .. }
                        | TrySubmitError::DeadlineUnmeetable { .. }),
                    ) => {
                        // Rejected-with-retry-after: BUSY carries the
                        // queue's own estimate of when to come back.
                        let _ = tx.try_send(Frame::busy(h.id, e.retry_after_ms().unwrap_or(0)));
                    }
                    Err(TrySubmitError::Closed) => {
                        let _ = tx.try_send(Frame::error(
                            h.id,
                            err_code::SHUTTING_DOWN,
                            "server shutting down",
                        ));
                        return;
                    }
                }
            }
            FrameKind::Stats => {
                let mut stats = handle.stats();
                stats.conn_threads = conns.load(Ordering::SeqCst);
                stats.model_resident_bytes = cfg.model_resident_bytes;
                stats.model_mapped_bytes = cfg.model_mapped_bytes;
                let mut text = stats.render_metrics();
                text.push_str(&format!("lb2_connections {}\n", conns.load(Ordering::SeqCst)));
                let _ = tx.try_send(Frame::stats_text(h.id, &text));
            }
            FrameKind::Health => {
                // The shutdown flag wins over the batcher's own view so a
                // probe racing the drain never reports Healthy.
                let state = if shutdown.load(Ordering::SeqCst) {
                    HealthState::Draining
                } else {
                    handle.health()
                };
                let _ = tx.try_send(Frame::health_report(h.id, state.code(), state.name()));
            }
            FrameKind::Shutdown => {
                let _ = tx.try_send(Frame::shutdown_ack(h.id));
                handle.set_draining();
                shutdown.store(true, Ordering::SeqCst);
                return;
            }
            other => {
                let _ = tx.try_send(Frame::error(
                    h.id,
                    err_code::PROTOCOL,
                    &format!("unexpected client frame kind {other:?}"),
                ));
                return;
            }
        }
    }
}

/// The per-connection completion funnel: all of a connection's in-flight
/// requests complete into its one writer channel.
struct ConnSink {
    tx: SyncSender<Frame>,
}

impl ReplySink for ConnSink {
    fn complete(&self, id: u64, outcome: RequestOutcome) {
        let frame = match outcome {
            RequestOutcome::Ok(resp) => Frame::result(id, &resp.output, resp.batch_size as u32),
            RequestOutcome::Expired => {
                Frame::error(id, err_code::DEADLINE, "deadline expired in queue")
            }
            RequestOutcome::Failed => {
                Frame::error(id, err_code::BACKEND, "backend failed the batch")
            }
        };
        // try_send: a worker thread must never block on a slow or dead
        // client's writer — a full/closed funnel drops the frame instead.
        let _ = self.tx.try_send(frame);
    }
}

/// Per-connection writer: drains the funnel to the socket. On a write
/// error it flips to discard mode (keeps draining so senders never see a
/// wedged channel) and exits once every sender — the reader and all
/// in-flight sinks — has dropped.
fn writer_loop<W: WriteHalf>(mut stream: W, rx: &Receiver<Frame>) {
    let mut dead = false;
    while let Ok(frame) = rx.recv() {
        if dead {
            continue;
        }
        if stream.write_all(&frame.encode()).is_err() {
            dead = true;
        }
    }
    let _ = stream.flush();
    stream.shutdown_conn();
}

#[cfg(test)]
mod tests {
    use super::super::client::WireClient;
    use super::*;
    use crate::linalg::Mat;

    fn echo_frontend(cfg: ServingConfig) -> TcpFrontend {
        TcpFrontend::start("127.0.0.1:0", cfg, |_w| |x: &Mat| -> Mat { x.clone() }).unwrap()
    }

    /// Loopback smoke: one request in, the echoed column out, stats sane.
    #[test]
    fn loopback_roundtrip() {
        let front = echo_frontend(ServingConfig::default());
        let mut client = WireClient::connect(front.local_addr()).unwrap();
        let out = client.infer(7, &[1.0, -2.5, 3.25], 0).unwrap();
        assert_eq!(out, vec![1.0, -2.5, 3.25]);
        let text = client.stats_text().unwrap();
        assert!(text.contains("lb2_requests_served_total 1"), "{text}");
        assert!(text.contains("lb2_connections 1"), "{text}");
        drop(client);
        let stats = front.shutdown();
        assert_eq!(stats.served, 1);
    }

    /// A SHUTDOWN frame from a client winds the whole front-end down.
    #[test]
    fn client_initiated_shutdown() {
        let front = echo_frontend(ServingConfig::default());
        let mut client = WireClient::connect(front.local_addr()).unwrap();
        client.shutdown_server().unwrap();
        for _ in 0..200 {
            if front.is_shutting_down() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(front.is_shutting_down());
        front.shutdown();
    }
}
