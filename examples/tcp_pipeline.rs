//! TCP serving demo: the whole wire in one process — compress a synthetic
//! chain, serve it on a loopback `TcpFrontend`, hammer it with concurrent
//! wire-protocol clients, and verify every response bit-matches the
//! in-process forward before draining the server with a SHUTDOWN frame.
//!
//! This is the network edition of `examples/serve.rs`: requests from many
//! sockets coalesce into single `forward_batch_into` calls (cross-
//! connection dynamic batching), admission control answers BUSY instead
//! of queueing unboundedly, and the metrics frame shows the batch-fill
//! histogram the batching bought.
//!
//! ```bash
//! cargo run --release --example tcp_pipeline [clients] [requests_per_client] [d] [bpp]
//! ```

use littlebit2::coordinator::{MethodStackBackend, ServerConfig};
use littlebit2::littlebit::InitStrategy;
use littlebit2::model::MethodStack;
use littlebit2::parallel::Pool;
use littlebit2::quant::MethodSpec;
use littlebit2::rng::Pcg64;
use littlebit2::serving::{ServingConfig, TcpFrontend, WireClient};
use littlebit2::spectral::{synth_weight, SynthSpec};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let clients: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(4);
    let per_client: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(64);
    let d: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(256);
    let bpp: f64 = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(0.55);

    // Compress a depth-2 chain (the quantize-once half).
    let mut rng = Pcg64::seed(7);
    let spec = MethodSpec::parse("littlebit2", bpp, InitStrategy::JointItq { iters: 30 })?;
    let t0 = Instant::now();
    let layers = (0..2)
        .map(|_| {
            let w = synth_weight(
                &SynthSpec { rows: d, cols: d, gamma: 0.3, coherence: 0.7, scale: 1.0 },
                &mut rng,
            );
            spec.compressor().compress_layer(&w, Pool::serial(), &mut rng)
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let stack = Arc::new(MethodStack::uniform("littlebit2", layers)?);
    println!(
        "compressed depth-{} chain ({d}x{d}, bpp {bpp}) in {:.2}s | serving form {} bytes",
        stack.depth(),
        t0.elapsed().as_secs_f64(),
        stack.storage_bytes()
    );

    // Serve it over loopback TCP (the serve-from-many half).
    let cfg = ServingConfig {
        expect_width: Some(stack.d_in()),
        batch: ServerConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            workers: 2,
            ..Default::default()
        },
        ..Default::default()
    };
    let backend_stack = Arc::clone(&stack);
    let front = TcpFrontend::start("127.0.0.1:0", cfg, move |_worker| {
        MethodStackBackend::new(Arc::clone(&backend_stack), 1)
    })?;
    let addr = front.local_addr();
    println!("listening on {addr}; driving {clients} client(s) x {per_client} request(s)");

    let t1 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..clients {
        let stack = Arc::clone(&stack);
        threads.push(std::thread::spawn(move || -> anyhow::Result<usize> {
            let mut client = WireClient::connect(addr)?;
            let mut rng = Pcg64::seed(100 + c as u64);
            let mut mismatches = 0;
            for r in 0..per_client {
                let mut x = vec![0.0f32; stack.d_in()];
                rng.fill_normal(&mut x);
                let got = client.infer((c * per_client + r) as u64, &x, 0)?;
                let want = stack.forward(&x);
                if got.len() != want.len()
                    || got.iter().zip(&want).any(|(a, b)| a.to_bits() != b.to_bits())
                {
                    mismatches += 1;
                }
            }
            Ok(mismatches)
        }));
    }
    let mut mismatches = 0;
    for t in threads {
        mismatches += t.join().expect("client thread")?;
    }
    let wall = t1.elapsed().as_secs_f64();

    let mut probe = WireClient::connect(addr)?;
    println!("\n--- server metrics ---\n{}", probe.stats_text()?);
    probe.shutdown_server()?;
    let stats = front.shutdown();

    let total = clients * per_client;
    println!(
        "{total} requests in {wall:.3}s ({:.0} req/s) | batches {} (mean size {:.1}) | verify: {}",
        total as f64 / wall.max(1e-9),
        stats.batches,
        stats.mean_batch,
        if mismatches == 0 { "every response bit-identical to in-process forward".to_string() }
        else { format!("{mismatches} MISMATCHES") },
    );
    anyhow::ensure!(mismatches == 0, "wire responses diverged from in-process forward");
    Ok(())
}
