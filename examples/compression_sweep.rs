//! Compression sweep over a synthetic-LLM zoo: the coordinator's parallel
//! job pipeline compressing every layer of a miniature Llama-style model at
//! several budgets — the workload behind Fig. 10 and the model-level side
//! of Table 1.
//!
//! ```bash
//! cargo run --release --example compression_sweep [blocks] [shrink]
//! ```

use littlebit2::coordinator::{run_compression_jobs, CompressionJob};
use littlebit2::rng::derive_seed;
use littlebit2::littlebit::{CompressionConfig, InitStrategy};
use littlebit2::model::{zoo, ArchSpec};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let blocks: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(2);
    let shrink: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(32);

    let arch = ArchSpec::llama2_7b();
    println!(
        "zoo: {} × {blocks} blocks, dims ÷{shrink} — {} layers per strategy\n",
        arch.name,
        blocks * 7
    );

    for bpp in [1.0, 0.55] {
        for strategy in [InitStrategy::Standard, InitStrategy::JointItq { iters: 30 }] {
            let layers = zoo::fabricate(&arch, shrink, blocks, 77);
            let jobs: Vec<CompressionJob> = layers
                .into_iter()
                .enumerate()
                .map(|(i, l)| {
                    CompressionJob::dense(
                        format!("b{}.{}", l.block, l.proj.name()),
                        l.weight,
                        CompressionConfig { bpp, strategy, residual: true, ..Default::default() },
                        derive_seed(500, i as u64),
                    )
                })
                .collect();
            let t0 = std::time::Instant::now();
            let workers = std::thread::available_parallelism()?.get();
            let results = run_compression_jobs(jobs, workers)?;
            let dt = t0.elapsed().as_secs_f64();
            let mean_mse: f64 = results.iter().map(|r| r.mse).sum::<f64>() / results.len() as f64;
            let mean_bpp: f64 = results.iter().map(|r| r.bpp).sum::<f64>() / results.len() as f64;
            println!(
                "bpp={bpp:<5} {:<12} layers={} mean_MSE={mean_mse:.4e} mean_bpp={mean_bpp:.3} wall={dt:.1}s ({} workers)",
                strategy.label(),
                results.len(),
                workers
            );
        }
    }

    println!("\nper-layer detail (0.55 bpp, littlebit2, first block):");
    let layers = zoo::fabricate(&arch, shrink, 1, 77);
    let jobs: Vec<CompressionJob> = layers
        .into_iter()
        .enumerate()
        .map(|(i, l)| {
            CompressionJob::dense(
                format!("{} (γ={:.2})", l.proj.name(), l.gamma),
                l.weight,
                CompressionConfig {
                    bpp: 0.55,
                    strategy: InitStrategy::JointItq { iters: 30 },
                    residual: true,
                    ..Default::default()
                },
                derive_seed(900, i as u64),
            )
        })
        .collect();
    for r in run_compression_jobs(jobs, 2)? {
        println!(
            "  {:<22} rank={:>3} mse={:.4e} bpp={:.3} ({:.0} ms)",
            r.name, r.rank, r.mse, r.bpp, r.wall_ms
        );
    }
    Ok(())
}
