//! Quickstart: compress one weight matrix with LittleBit-2 and run the
//! MatMul-free inference path, end to end, in under a minute.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the whole public API surface: synthesize a heavy-tailed weight →
//! compress at 0.55 bpp with each initialization strategy → compare MSE
//! (the Table 3 ordering) → pack the winner into bit-level layers and
//! check the packed forward against a dense matvec.

use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
use littlebit2::quant::tiny_rank_fp16;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{estimate_gamma, synth_weight, SynthSpec};

fn main() -> anyhow::Result<()> {
    let mut rng = Pcg64::seed(2026);

    // 1. A synthetic "LLM layer": heavy-tailed spectrum (γ=0.27, the paper's
    //    Llama-2 median) with coherent (spiky) singular vectors.
    let spec = SynthSpec { rows: 512, cols: 512, gamma: 0.27, coherence: 0.75, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);
    let svd = littlebit2::linalg::svd_randomized(&w, 128, 10, 2, &mut rng);
    let fit = estimate_gamma(&svd.s);
    println!(
        "weight 512x512: measured γ = {:.3} (heavy-tailed: {})",
        fit.gamma,
        fit.is_heavy_tailed()
    );

    // 2. Compress at 0.55 bpp with all strategies + the FP16 baseline.
    let bpp = 0.55;
    let r_fp = littlebit2::memory::tiny_rank_for_budget(512, 512, bpp);
    let fp = tiny_rank_fp16(&w, r_fp, &mut rng);
    println!("\n--- reconstruction MSE at {bpp} bpp ---");
    println!("tinyrank-fp16 (r={r_fp:>3})        {:.4e}", fp.reconstruction.mse(&w));

    let mut best = None;
    for strategy in [
        InitStrategy::Standard,
        InitStrategy::RandomRotation,
        InitStrategy::JointItq { iters: 50 },
    ] {
        let mut rng = Pcg64::seed(7);
        let cfg = CompressionConfig { bpp, strategy, residual: true, ..Default::default() };
        let c = compress(&w, &cfg, &mut rng);
        let mse = c.reconstruct().mse(&w);
        println!(
            "{:<14}(r={:>3}, 2 paths) {:.4e}   [bpp used: {:.3}]",
            strategy.label(),
            c.paths[0].factors.rank(),
            mse,
            c.bpp()
        );
        best = Some(c);
    }
    let best = best.expect("compressed");

    // 3. Deploy: pack into bit matrices and serve a matvec without any
    //    FP weight multiply (§6.2's MatMul-free path).
    let mut x = vec![0.0f32; 512];
    rng.fill_normal(&mut x);
    let y_packed = best.forward_packed(&x);
    let y_dense = best.reconstruct().matvec(&x);
    let err: f32 = y_packed
        .iter()
        .zip(&y_dense)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    let (adds, mults) = best.paths[0].pack().op_counts();
    println!(
        "\npacked forward: max |packed - dense| = {err:.2e}; per path {adds} sign-adds + {mults} fp-mults (vs {} fp-MACs dense)",
        512 * 512
    );
    println!("storage: {} bits = {:.3} bpp", best.storage_bits(), best.bpp());
    Ok(())
}
