//! Batched serving demo: the dynamic batcher + multi-worker pool in front
//! of the MatMul-free packed tri-scale stack (§6.2's deployment path).
//! Each drained batch runs as ONE **fused** batched sign-GEMM forward —
//! scales folded into the kernels, row ranges on the persistent
//! `SignPool`, buffers reused via `BatchScratch` — so a steady-state batch
//! allocates nothing and spawns nothing.
//!
//! The model comes from a `.lb2` artifact when one is given (the
//! quantize-once / serve-from-many deployment story:
//! `littlebit2 compress --out model.lb2` first), and falls back to
//! fabricating + compressing a synthetic layer in-process. In fabricate
//! mode the report additionally covers the kernel-level dense-vs-packed
//! comparison (the dense reference only exists there) and the
//! fused-pool-vs-scoped-unfused engine ratio (PR 2's tentpole).
//!
//! ```bash
//! cargo run --release --example serve [model.lb2] [n_requests] [d] [bpp] [workers] [threads]
//! ```
//!
//! A leading argument that doesn't parse as a number is treated as the
//! artifact path; all numeric arguments keep their positions after it.

use littlebit2::coordinator::{InferenceServer, PackedStackBackend, ServerConfig};
use littlebit2::linalg::Mat;
use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
use littlebit2::model::PackedStack;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let model_path = match args.first() {
        Some(a) if a.parse::<usize>().is_err() => Some(args.remove(0)),
        _ => None,
    };
    let n_requests: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(256);
    let d: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(1024);
    let bpp: f64 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0.55);
    let workers: usize = args.get(3).map(|s| s.parse()).transpose()?.unwrap_or(2);
    let threads: usize = args.get(4).map(|s| s.parse()).transpose()?.unwrap_or(1);

    let mut rng = Pcg64::seed(1);
    // Load the artifact when given; otherwise fabricate + compress a
    // synthetic layer (keeping the dense weight as the kernel baseline).
    let (stack, dense) = match &model_path {
        Some(path) => {
            println!("loading {path} ...");
            let stack = PackedStack::load(path)?;
            println!(
                "loaded: depth {} | {} -> {} features | packed weights {} bytes",
                stack.depth(),
                stack.d_in(),
                stack.d_out(),
                stack.storage_bytes()
            );
            (Arc::new(stack), None)
        }
        None => {
            println!("no artifact given; compressing a {d}x{d} layer at {bpp} bpp ...");
            let spec = SynthSpec { rows: d, cols: d, gamma: 0.3, coherence: 0.7, scale: 1.0 };
            let w = synth_weight(&spec, &mut rng);
            let cfg = CompressionConfig {
                bpp,
                strategy: InitStrategy::JointItq { iters: 30 },
                residual: true,
                ..Default::default()
            };
            // Pack once at load time; all workers share the read-only model.
            let stack = compress(&w, &cfg, &mut rng).pack_stack();
            (Arc::new(stack), Some(w))
        }
    };
    let d_in = stack.d_in();

    let server = InferenceServer::start_pool(
        ServerConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_depth: 1024,
            workers,
            ..Default::default()
        },
        |_worker| PackedStackBackend::new(Arc::clone(&stack), threads),
    );
    let mut inputs = Vec::new();
    for _ in 0..n_requests {
        let mut x = vec![0.0f32; d_in];
        rng.fill_normal(&mut x);
        inputs.push(x);
    }

    println!(
        "serving {n_requests} requests on {workers} worker(s), {threads} kernel thread(s) ..."
    );
    let t0 = Instant::now();
    let rxs: Vec<_> = inputs
        .into_iter()
        .enumerate()
        .map(|(i, x)| server.submit(i as u64, x))
        .collect();
    for rx in rxs {
        let _ = rx.recv()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    println!(
        "throughput {:.0} req/s (server-reported {:.0} tok/s) | batches {} (mean size {:.1}, mean kernel rate {:.0} tok/s) | p50 {:.2} ms p99 {:.2} ms",
        n_requests as f64 / wall,
        stats.tokens_per_s,
        stats.batches,
        stats.mean_batch,
        stats.mean_batch_tokens_per_s,
        stats.p50_ms,
        stats.p99_ms
    );

    // Kernel-level comparison needs the dense reference weight — only
    // available in fabricate mode (a loaded artifact carries packed signs
    // and scales, deliberately not the FP teacher).
    let Some(w) = dense else { return Ok(()) };
    let model = &stack.layers()[0];

    // Dense FP32 GEMV vs the packed pipeline at batch 1 (GEMV) and batch
    // 32 (sign-GEMM).
    let mut x = vec![0.0f32; d];
    rng.fill_normal(&mut x);
    let mut y = vec![0.0f32; d];
    let reps = 50;
    let t0 = Instant::now();
    for _ in 0..reps {
        littlebit2::packing::gemv_dense(&w, &x, &mut y);
    }
    let dense_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    // Allocation-free single-request path, same as the gemv_speedup bench —
    // keeps the batch-1 number comparable to the dense loop's reused buffer.
    let mut scratch = littlebit2::packing::Scratch::default();
    let mut out = vec![0.0f32; d];
    let t0 = Instant::now();
    for _ in 0..reps {
        model.forward_into(&x, &mut out, &mut scratch);
        std::hint::black_box(&out);
    }
    let packed_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;

    let b = 32;
    let mut xb = Mat::zeros(d, b);
    xb.fill_normal(&mut rng);
    // Fused pool path, allocation-free (the serving hot loop).
    let pool = littlebit2::packing::SignPool::global();
    let mut bscratch = littlebit2::packing::BatchScratch::default();
    let mut yb = Mat::default();
    model.forward_batch_into(&xb, &mut yb, &mut bscratch, pool, threads); // warmup
    let t0 = Instant::now();
    for _ in 0..reps {
        model.forward_batch_into(&xb, &mut yb, &mut bscratch, pool, threads);
        std::hint::black_box(&yb);
    }
    let batch_ms_per_item = t0.elapsed().as_secs_f64() * 1e3 / (reps * b) as f64;

    // PR 1 baseline at the same shape/threads: unfused scale passes +
    // per-call scoped thread spawns (bit-identical output, slower engine).
    std::hint::black_box(model.forward_batch_scoped(&xb, threads)); // warmup
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(model.forward_batch_scoped(&xb, threads));
    }
    let scoped_ms_per_item = t0.elapsed().as_secs_f64() * 1e3 / (reps * b) as f64;

    println!(
        "kernel-level: dense {dense_ms:.3} ms vs packed {packed_ms:.3} ms → {:.1}x at batch 1; {batch_ms_per_item:.3} ms/item → {:.1}x at batch {b} (paper: 11.6x on 70B-MLP CUDA)",
        dense_ms / packed_ms,
        dense_ms / batch_ms_per_item
    );
    println!(
        "engine: fused-pool {batch_ms_per_item:.3} ms/item vs scoped-unfused {scoped_ms_per_item:.3} ms/item at batch {b} → {:.2}x (bit-identical outputs)",
        scoped_ms_per_item / batch_ms_per_item
    );
    Ok(())
}
