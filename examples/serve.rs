//! Batched serving demo: the dynamic batcher in front of the MatMul-free
//! packed tri-scale stack (§6.2's deployment path), reporting throughput
//! and latency percentiles against a dense-FP32 backend at the same shape.
//!
//! ```bash
//! cargo run --release --example serve [n_requests] [d] [bpp]
//! ```

use littlebit2::coordinator::InferenceServer;
use littlebit2::littlebit::{compress, CompressionConfig, InitStrategy};
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(256);
    let d: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(1024);
    let bpp: f64 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0.55);

    println!("compressing a {d}x{d} layer at {bpp} bpp ...");
    let mut rng = Pcg64::seed(1);
    let spec = SynthSpec { rows: d, cols: d, gamma: 0.3, coherence: 0.7, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);
    let cfg = CompressionConfig {
        bpp,
        strategy: InitStrategy::JointItq { iters: 30 },
        residual: true,
        ..Default::default()
    };
    let compressed = compress(&w, &cfg, &mut rng);
    let layers: Vec<_> = compressed.paths.iter().map(|p| p.pack()).collect();

    // Backend: the packed MatMul-free forward, one call per batch item.
    let backend = move |batch: &[Vec<f32>]| -> Vec<Vec<f32>> {
        batch
            .iter()
            .map(|x| {
                let mut out = layers[0].forward(x);
                for layer in &layers[1..] {
                    for (o, v) in out.iter_mut().zip(layer.forward(x)) {
                        *o += v;
                    }
                }
                out
            })
            .collect()
    };

    let server = InferenceServer::start(16, Duration::from_millis(2), 1024, backend);
    let mut inputs = Vec::new();
    for _ in 0..n_requests {
        let mut x = vec![0.0f32; d];
        rng.fill_normal(&mut x);
        inputs.push(x);
    }

    println!("serving {n_requests} requests ...");
    let t0 = Instant::now();
    let rxs: Vec<_> = inputs
        .into_iter()
        .enumerate()
        .map(|(i, x)| server.submit(i as u64, x))
        .collect();
    for rx in rxs {
        let _ = rx.recv()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    println!(
        "throughput {:.0} req/s | batches {} (mean size {:.1}) | p50 {:.2} ms p99 {:.2} ms",
        n_requests as f64 / wall,
        stats.batches,
        stats.mean_batch,
        stats.p50_ms,
        stats.p99_ms
    );

    // Dense-FP32 comparison at the same shape (single-threaded, unbatched).
    let mut x = vec![0.0f32; d];
    rng.fill_normal(&mut x);
    let mut y = vec![0.0f32; d];
    let t0 = Instant::now();
    let reps = 50;
    for _ in 0..reps {
        littlebit2::packing::gemv_dense(&w, &x, &mut y);
    }
    let dense_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    let t0 = Instant::now();
    let packed: Vec<_> = compressed.paths.iter().map(|p| p.pack()).collect();
    for _ in 0..reps {
        let mut out = packed[0].forward(&x);
        for layer in &packed[1..] {
            for (o, v) in out.iter_mut().zip(layer.forward(&x)) {
                *o += v;
            }
        }
    }
    let packed_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    println!(
        "kernel-level: dense {dense_ms:.3} ms vs packed {packed_ms:.3} ms → {:.1}x (paper: 11.6x on 70B-MLP CUDA)",
        dense_ms / packed_ms
    );
    Ok(())
}
