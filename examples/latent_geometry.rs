//! Latent geometry diagnostics: regenerates the data behind Figs. 1, 3, 4
//! and 5 — λ per latent row, histogram evolution SVD → Rotation → Joint-ITQ,
//! and the kurtosis/λ statistics quoted in §4.2-4.4.
//!
//! ```bash
//! cargo run --release --example latent_geometry [size] [gamma] [coherence]
//! ```

use littlebit2::linalg::{svd_randomized, Mat};
use littlebit2::littlebit::{joint_itq, random_rotation};
use littlebit2::quant::row_distortions;
use littlebit2::rng::Pcg64;
use littlebit2::spectral::{synth_weight, SynthSpec};

fn stats(name: &str, m: &Mat) {
    let lam = row_distortions(m);
    let mean = lam.iter().sum::<f64>() / lam.len() as f64;
    let max = lam.iter().fold(0.0f64, |a, &b| a.max(b));
    // Kurtosis of the entries (Fisher, excess+3) — §4.2 quotes ≈16.8 for
    // raw SVD factors of Llama-2 q_proj.
    let xs: Vec<f64> = m.to_vec().iter().map(|&x| x as f64).collect();
    let n = xs.len() as f64;
    let mu = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mu).powi(2)).sum::<f64>() / n;
    let m4 = xs.iter().map(|x| (x - mu).powi(4)).sum::<f64>() / n;
    let kurt = m4 / (var * var);
    println!("{name:<18} λ_mean={mean:.3}  λ_max={max:.3}  kurtosis={kurt:.1}");

    // Coarse histogram of the first latent dimension (Fig 4/5 visual).
    let col = m.col(0);
    let absmax = col.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-9);
    let mut bins = [0usize; 11];
    for &v in &col {
        let idx = (((v / absmax) + 1.0) / 2.0 * 10.0).round() as usize;
        bins[idx.min(10)] += 1;
    }
    let peak = *bins.iter().max().expect("bins") as f64;
    print!("{:<18} ", "  dim-0 hist");
    for b in bins {
        let h = (b as f64 / peak * 9.0).round() as usize;
        print!("{}", char::from_digit(h as u32, 10).expect("digit"));
    }
    println!("   (-max .. +max)");
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let size: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(1024);
    let gamma: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(0.3);
    let coherence: f64 = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(0.8);
    let rank = (size / 16).max(8);

    println!("Latent Geometry Alignment — W {size}x{size}, γ={gamma}, coherence={coherence}, r={rank}\n");
    let mut rng = Pcg64::seed(15);
    let spec = SynthSpec { rows: size, cols: size, gamma, coherence, scale: 1.0 };
    let w = synth_weight(&spec, &mut rng);

    let svd = svd_randomized(&w, rank, 10, 2, &mut rng);
    let (u, v) = svd.split_factors();

    // (a) raw SVD factors — the misaligned geometry of Fig 1a / Fig 3 "LB".
    stats("svd (raw)", &u);

    // (b) random rotation — Gaussian limit E[λ] ≈ 0.3634 (Theorem 4.4).
    let rot = random_rotation(rank, &mut rng);
    stats("random rotation", &u.matmul(&rot));

    // (c) Joint-ITQ — bimodal alignment, λ below the Gaussian limit (§4.4).
    let t0 = std::time::Instant::now();
    let (itq_rot, report) = joint_itq(&u, &v, 50, &mut rng);
    let dt = t0.elapsed().as_secs_f64();
    stats("joint-itq (T=50)", &u.matmul(&itq_rot));
    println!(
        "\nITQ convergence: objective {:.1} → {:.1} over {} iters ({dt:.2}s; paper: ~3s at 4096²)",
        report.objective.first().expect("trace"),
        report.objective.last().expect("trace"),
        report.iters
    );
    println!("reference points: λ worst-case ≈ 1.0, Gaussian limit = 1 - 2/π ≈ 0.3634");
    Ok(())
}
