//! The method matrix: every registered compression method run over the
//! same 3-layer synthetic chain through the full generic engine —
//! `quant::Compressor` → `model::MethodStack` → `.lb2` v2 bytes — with a
//! fidelity / bpp / size table at the end (the Table 1 shape, minus the
//! GPU perplexity columns).
//!
//! ```bash
//! cargo run --release --example method_matrix [d_model] [bpp]
//! ```

use littlebit2::linalg::Mat;
use littlebit2::littlebit::InitStrategy;
use littlebit2::model::MethodStack;
use littlebit2::parallel::Pool;
use littlebit2::quant::{MethodSpec, METHOD_NAMES};
use littlebit2::rng::{derive_seed, Pcg64};
use littlebit2::spectral::{synth_weight, SynthSpec};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let d: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(96);
    let bpp: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(1.0);

    // One 3-layer heavy-tailed chain (d → 2d → d, the FFN shape); every
    // method compresses the SAME weights.
    let dims = [d, 2 * d, d];
    let mut wrng = Pcg64::seed(17);
    let weights: Vec<Mat> = dims
        .windows(2)
        .map(|w| {
            let spec =
                SynthSpec { rows: w[1], cols: w[0], gamma: 0.3, coherence: 0.7, scale: 1.0 };
            synth_weight(&spec, &mut wrng)
        })
        .collect();
    let params: usize = weights.iter().map(|w| w.rows() * w.cols()).sum();
    println!("chain: {d} → {} → {d} ({params} params), budget {bpp} bpp where budgeted\n", 2 * d);

    println!(
        "{:<11} {:>12} {:>9} {:>9} {:>12} {:>11} {:>9}",
        "method", "rel_err", "bpp_decl", "bpp_disk", "artifact_B", "compress_ms", "serve_ok"
    );
    for (mi, name) in METHOD_NAMES.iter().enumerate() {
        let spec = MethodSpec::parse(name, bpp, InitStrategy::JointItq { iters: 30 })?;
        let compressor = spec.compressor();
        let mut rng = Pcg64::seed(derive_seed(23, mi as u64));
        let mut layers = Vec::new();
        let mut err_num = 0.0f64;
        let mut err_den = 0.0f64;
        // Time compression alone; the scoring reconstruction below is
        // excluded, matching `eval` / EXPERIMENTS.md §Baselines.
        let mut compress_ms = 0.0f64;
        for w in &weights {
            let t = std::time::Instant::now();
            let layer = compressor.compress_layer(w, Pool::global(), &mut rng)?;
            compress_ms += t.elapsed().as_secs_f64() * 1e3;
            err_num += layer.reconstruct_on(Pool::global()).fro_dist2(w);
            err_den += w.fro_norm().powi(2);
            layers.push(layer);
        }
        let stack = MethodStack::uniform(name, layers)?;

        // Through the artifact: bytes out, loaded back, forwards must be
        // bit-identical to the in-memory stack.
        let bytes = stack.to_artifact_bytes()?;
        let loaded = MethodStack::from_artifact_bytes(&bytes)?;
        let mut x = Mat::zeros(d, 4);
        x.fill_normal(&mut Pcg64::seed(29));
        let serve_ok = loaded.forward_batch(&x) == stack.forward_batch(&x);

        println!(
            "{:<11} {:>12.4e} {:>9.3} {:>9.3} {:>12} {:>11.0} {:>9}",
            name,
            err_num / err_den,
            stack.declared_bits() as f64 / params as f64,
            bytes.len() as f64 * 8.0 / params as f64,
            bytes.len(),
            compress_ms,
            if serve_ok { "bit-exact" } else { "MISMATCH" },
        );
        assert!(serve_ok, "{name}: loaded artifact must forward bit-exactly");
    }
    println!(
        "\nbpp_decl = App. H accounting; bpp_disk = actual .lb2 v2 bytes (f32 scales,\n\
         tail-word padding, framing — and the f32 reconstruction for rtn/billm's\n\
         dense serving form). See EXPERIMENTS.md §Artifact for the reconciliation."
    );
    Ok(())
}
