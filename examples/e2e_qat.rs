//! END-TO-END DRIVER (the ARCHITECTURE.md validation run): train an FP teacher
//! transformer from scratch on the synthetic corpus, compress it into each
//! student variant with rust-native SVD→(rotation|Joint-ITQ)→Dual-SVID,
//! run QAKD through the AOT-compiled train-step artifacts via PJRT, and
//! report loss curves (Fig. 7), sign-flip ratios (Fig. 8), and held-out PPL
//! per variant (Table 3) — Python nowhere on the path.
//!
//! ```bash
//! make artifacts   # once: lowers python/compile → artifacts/*.hlo.txt
//! cargo run --release --example e2e_qat -- [teacher_steps] [student_steps] [variants]
//! # variants: comma list of tinyrank,littlebit,rotation,littlebit2 (default all)
//! ```
//!
//! The recorded run (EXPERIMENTS.md §E2E) uses the `small` preset:
//! 4-layer, d=128, vocab-512 transformer (~1.1M params).

use anyhow::Result;
use littlebit2::coordinator::{QatDriver, StudentVariant};
use std::fmt::Write as _;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let teacher_steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(300);
    let student_steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(200);
    let variants: Vec<StudentVariant> = match args.get(2) {
        None => vec![
            StudentVariant::TinyRankFp,
            StudentVariant::LittleBit,
            StudentVariant::RandomRotation,
            StudentVariant::LittleBit2 { itq_iters: 50 },
        ],
        Some(list) => list
            .split(',')
            .map(|v| match v {
                "tinyrank" => Ok(StudentVariant::TinyRankFp),
                "littlebit" => Ok(StudentVariant::LittleBit),
                "rotation" => Ok(StudentVariant::RandomRotation),
                "littlebit2" => Ok(StudentVariant::LittleBit2 { itq_iters: 50 }),
                other => anyhow::bail!("unknown variant {other}"),
            })
            .collect::<Result<_>>()?,
    };

    let driver = QatDriver::new("artifacts", 1234)?;
    let cfg = &driver.manifest.config;
    println!(
        "platform={} preset={} | transformer d={} L={} heads={} ff={} vocab={} seq={} batch={} bpp={}",
        driver.runtime().platform(),
        driver.manifest.preset,
        cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.d_ff, cfg.vocab, cfg.seq, cfg.batch, cfg.bpp
    );

    // --- Phase 1: teacher pretraining (plain CE) ---
    println!("\n== teacher: {teacher_steps} steps ==");
    let t0 = std::time::Instant::now();
    let (teacher, t_losses) = driver.train_teacher(teacher_steps, 1e-3, |s, l| {
        if s % 25 == 0 {
            println!("  step {s:>5}  loss {l:.4}");
        }
    })?;
    let teacher_ce = driver.eval_ce("teacher_eval", &teacher, 8)?;
    println!(
        "teacher done in {:.0}s: train loss {:.4} → {:.4}, held-out CE {:.4} (PPL {:.2})",
        t0.elapsed().as_secs_f64(),
        t_losses.first().unwrap(),
        t_losses.last().unwrap(),
        teacher_ce,
        teacher_ce.exp()
    );

    // --- Phase 2: QAKD per variant (Fig 7 / Fig 8 / Table 3) ---
    let mut summary = String::new();
    writeln!(
        summary,
        "\n{:<16} {:>10} {:>10} {:>10} {:>12} {:>10}",
        "variant", "loss[0]", "loss[end]", "eval CE", "PPL", "flip[0]"
    )?;
    for variant in variants {
        println!("\n== student {}: {student_steps} steps ==", variant.label());
        let t0 = std::time::Instant::now();
        let outcome = driver.train_student(&teacher, variant, student_steps, 1e-3, |s, l, f| {
            if s % 25 == 0 {
                println!("  step {s:>5}  loss {l:.4}  flip {f:.5}");
            }
        })?;
        println!(
            "{} done in {:.0}s — eval CE {:.4} (PPL {:.2})",
            variant.label(),
            t0.elapsed().as_secs_f64(),
            outcome.final_eval_ce,
            outcome.final_eval_ce.exp()
        );
        writeln!(
            summary,
            "{:<16} {:>10.4} {:>10.4} {:>10.4} {:>12.2} {:>10.5}",
            variant.label(),
            outcome.trace.losses.first().copied().unwrap_or(f32::NAN),
            outcome.trace.losses.last().copied().unwrap_or(f32::NAN),
            outcome.final_eval_ce,
            outcome.final_eval_ce.exp(),
            outcome.trace.flip_ratio.first().copied().unwrap_or(0.0),
        )?;
        // Dump the full traces for plotting (Fig 7/8 series).
        let path = format!("target/e2e_trace_{}.csv", variant.label().replace('+', "_"));
        let mut csv = String::from("step,loss,flip_ratio\n");
        for (i, (l, f)) in outcome
            .trace
            .losses
            .iter()
            .zip(&outcome.trace.flip_ratio)
            .enumerate()
        {
            writeln!(csv, "{i},{l},{f}")?;
        }
        std::fs::write(&path, csv)?;
        println!("trace written to {path}");
    }

    println!("{summary}");
    println!("teacher reference: eval CE {teacher_ce:.4} (PPL {:.2})", teacher_ce.exp());
    Ok(())
}
