# LittleBit-2 build entry points. `build`/`test`/`bench` are pure-rust and
# offline; `artifacts` lowers the L2/L1 JAX+Pallas graph to HLO text (needs
# a JAX environment) and is only required for the PJRT-gated paths
# (`--features xla`): the train CLI, examples/e2e_qat, tests/runtime_e2e.

.PHONY: build test bench artifacts doc

build:
	cargo build --release

test: build
	cargo test -q

bench:
	cargo bench

doc:
	cargo doc --no-deps

artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts
