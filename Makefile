# LittleBit-2 build entry points. `build`/`test`/`bench*`/`clippy` are
# pure-rust and offline; `artifacts` lowers the L2/L1 JAX+Pallas graph to
# HLO text (needs a JAX environment) and is only required for the
# PJRT-gated paths (`--features xla`): the train CLI, examples/e2e_qat,
# tests/runtime_e2e.

.PHONY: build test test-scalar bench bench-build bench-gemm bench-compress bench-load bench-cluster clippy artifacts doc roundtrip eval serve-smoke cluster-smoke chaos

build:
	cargo build --release

test: build
	cargo test -q

# The full suite with the scalar kernel lane pinned (the portable
# bit-exactness oracle; see packing::simd). CI runs this as the `scalar`
# leg of the build-test matrix so both lanes stay green on every push.
test-scalar: build
	LB2_FORCE_SCALAR=1 cargo test -q

# The deployment pipeline, end to end: quantize a tiny model once, persist
# it as a versioned .lb2 artifact, then load + serve a batch of requests
# from it on the worker pool. Run by the build-test CI job so
# compress→save→load→serve stays green. (`serve` fails loudly on a
# corrupt/truncated artifact — see ARCHITECTURE.md "Artifact format".)
# The --jobs 4 re-run + cmp asserts the parallel-compression determinism
# contract: worker count must not change a single artifact byte.
roundtrip: build
	cargo run --release -- compress --size 48 --layers 2 --bpp 1.0 --out target/roundtrip.lb2
	cargo run --release -- compress --size 48 --layers 2 --bpp 1.0 --jobs 4 --out target/roundtrip_jobs4.lb2
	cmp target/roundtrip.lb2 target/roundtrip_jobs4.lb2
	cargo run --release -- serve --model target/roundtrip.lb2 --workers 2 --batch 8 --requests 32
	# Second pass through the method-generic spine: a non-LittleBit-2
	# method (OneBit) must survive the same compress→save→load→serve loop.
	cargo run --release -- compress --method onebit --size 48 --layers 2 --out target/roundtrip_onebit.lb2
	cargo run --release -- serve --model target/roundtrip_onebit.lb2 --workers 2 --batch 8 --requests 32
	# Third pass, zero-copy: the v3 aligned encoding served through the
	# mmap loader (both CI lanes run this, so the borrowed planes feed the
	# scalar oracle and the AVX2 kernels alike).
	cargo run --release -- compress --size 48 --layers 2 --bpp 1.0 --aligned 1 --out target/roundtrip_v3.lb2
	cargo run --release -- serve --model target/roundtrip_v3.lb2 --mmap 1 --workers 2 --batch 8 --requests 32

# Loopback TCP smoke: compress a tiny model, `serve --listen` it in the
# background, then drive 64 pipelined requests over 4 connections with
# the sequential-replay bit-identity check (--verify 1: every wire reply
# must be byte-for-byte stable across batch shapes), scrape the metrics
# frame, and shut the server down over the wire. `wait` propagates the
# server's exit code so either side of the socket failing fails the
# target; --serve-secs 60 is the watchdog that unhangs CI if the client
# dies before sending SHUTDOWN. Run by the build-test CI job.
serve-smoke: build
	cargo run --release -- compress --size 48 --layers 2 --bpp 1.0 --out target/serve_smoke.lb2
	cargo run --release -- serve --model target/serve_smoke.lb2 --listen 127.0.0.1:41512 --workers 2 --batch 8 --serve-secs 60 & \
	srv=$$!; \
	sleep 1; \
	rc=0; \
	cargo run --release -- client --connect 127.0.0.1:41512 --width 48 --requests 64 --concurrency 4 --verify 1 --stats 1 --shutdown 1 || rc=$$?; \
	wait $$srv || rc=$$?; \
	exit $$rc

# Sharded serving smoke, both shard modes over real loopback sockets.
# Pipeline pass: tracker + 2 peers (one eager, one mmap), bit-identity
# verified requests through the tracker, then ONE PEER IS KILLED
# mid-run and the verified client pass repeats against the re-sharded
# survivor before a wire SHUTDOWN drains the cluster. The tracker exits
# non-zero if its exactly-once ledger does not reconcile
# (accepted != served + failed + deadline-missed), and `wait` propagates
# that, so a lost request fails the target. Row-shard pass: same cluster
# shape, every peer holding row shards of every layer, verified and
# drained. Background processes run the built binary directly (not
# `cargo run`) so `kill` reaches the server process itself; --serve-secs
# watchdogs unhang CI if either side dies early. Run by the build-test
# CI job in both SIMD lanes.
cluster-smoke: build
	cargo run --release -- compress --size 48 --layers 3 --bpp 1.0 --aligned 1 --out target/cluster_smoke.lb2
	target/release/littlebit2 tracker --model target/cluster_smoke.lb2 --listen 127.0.0.1:41713 --peers 2 --mode pipeline --heartbeat-ms 750 --serve-secs 90 & \
	trk=$$!; \
	target/release/littlebit2 peer --model target/cluster_smoke.lb2 --tracker 127.0.0.1:41713 --serve-secs 90 & \
	p1=$$!; \
	target/release/littlebit2 peer --model target/cluster_smoke.lb2 --tracker 127.0.0.1:41713 --mmap 1 --serve-secs 90 & \
	p2=$$!; \
	sleep 2; \
	rc=0; \
	cargo run --release -- client --connect 127.0.0.1:41713 --width 48 --requests 32 --concurrency 2 --verify 1 || rc=$$?; \
	kill $$p2; \
	cargo run --release -- client --connect 127.0.0.1:41713 --width 48 --requests 32 --concurrency 2 --verify 1 --stats 1 --shutdown 1 || rc=$$?; \
	wait $$trk || rc=$$?; \
	wait $$p1 || rc=$$?; \
	exit $$rc
	target/release/littlebit2 tracker --model target/cluster_smoke.lb2 --listen 127.0.0.1:41714 --peers 2 --mode rowshard --heartbeat-ms 750 --serve-secs 90 & \
	trk=$$!; \
	target/release/littlebit2 peer --model target/cluster_smoke.lb2 --tracker 127.0.0.1:41714 --serve-secs 90 & \
	p1=$$!; \
	target/release/littlebit2 peer --model target/cluster_smoke.lb2 --tracker 127.0.0.1:41714 --serve-secs 90 & \
	p2=$$!; \
	sleep 2; \
	rc=0; \
	cargo run --release -- client --connect 127.0.0.1:41714 --width 48 --requests 32 --concurrency 2 --verify 1 --stats 1 --shutdown 1 || rc=$$?; \
	wait $$trk || rc=$$?; \
	wait $$p1 || rc=$$?; \
	wait $$p2 || rc=$$?; \
	exit $$rc

# The chaos soak (tests/chaos_soak.rs): the serving stack under seeded
# fault injection at the wire AND backend boundaries, driven by retrying
# clients until every request is answered bit-identical to the in-process
# forward, with exactly-once counter reconciliation and a deadlock
# watchdog. One seed fully determines the fault schedule — override
# LB2_CHAOS_SEED to explore, and replay a red CI run locally with the
# seed it prints. Run by the build-test CI job next to serve-smoke.
# 3298842093 == 0xC4A055ED, the harness's built-in default.
LB2_CHAOS_SEED ?= 3298842093
chaos: build
	LB2_CHAOS_SEED=$(LB2_CHAOS_SEED) cargo test --release --test chaos_soak -- --nocapture

# The methods × bpp fidelity/throughput sweep (Table 1 shape) at bounded
# sizes; refreshes BENCH_methods.json at the repo root. Run by the
# build-test CI job so every method stays green through the real pipeline.
eval: build
	cargo run --release -- eval --size 64 --blocks 1 --jobs 2 --requests 64 --out BENCH_methods.json

bench:
	cargo bench

# Compile every bench without running (the CI bench gate).
bench-build:
	cargo bench --no-run

# The sign-GEMM engine sweep; refreshes BENCH_gemm.json at the repo root
# (the cross-PR perf-trajectory record — see EXPERIMENTS.md #Fused).
bench-gemm:
	cargo bench --bench gemm_speedup

# The offline-pipeline sweep: layer-parallel + linalg-parallel compression
# throughput; refreshes BENCH_compress.json at the repo root and asserts
# byte-identical artifacts across worker counts (EXPERIMENTS.md
# #Compression-throughput).
bench-compress:
	cargo bench --bench compress_speedup

# Eager vs mmap load latency (cold/warm load, RSS delta,
# time-to-first-response); refreshes BENCH_load.json at the repo root
# (EXPERIMENTS.md #Load-latency).
bench-load:
	cargo bench --bench load_latency

# Cluster scaling: serial throughput and latency quantiles vs peer count
# for both shard modes over loopback; refreshes BENCH_cluster.json at the
# repo root (EXPERIMENTS.md #Cluster-scaling).
bench-cluster:
	cargo bench --bench cluster_scaling

clippy:
	cargo clippy --all-targets -- -D warnings

doc:
	cargo doc --no-deps

artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts
