# LittleBit-2 build entry points. `build`/`test`/`bench*`/`clippy` are
# pure-rust and offline; `artifacts` lowers the L2/L1 JAX+Pallas graph to
# HLO text (needs a JAX environment) and is only required for the
# PJRT-gated paths (`--features xla`): the train CLI, examples/e2e_qat,
# tests/runtime_e2e.

.PHONY: build test bench bench-build bench-gemm clippy artifacts doc

build:
	cargo build --release

test: build
	cargo test -q

bench:
	cargo bench

# Compile every bench without running (the CI bench gate).
bench-build:
	cargo bench --no-run

# The sign-GEMM engine sweep; refreshes BENCH_gemm.json at the repo root
# (the cross-PR perf-trajectory record — see EXPERIMENTS.md #Fused).
bench-gemm:
	cargo bench --bench gemm_speedup

clippy:
	cargo clippy --all-targets -- -D warnings

doc:
	cargo doc --no-deps

artifacts:
	cd python/compile && python3 aot.py --out-dir ../../artifacts
