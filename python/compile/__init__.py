"""Build-time compile package: L2 jax model + L1 Pallas kernels + AOT lowering.

Nothing in this package is imported at run time; the rust coordinator only
consumes the HLO text artifacts and the manifest that `compile.aot` emits.
"""
