"""AOT lowering: L2/L1 jax graphs → HLO text artifacts for the rust runtime.

Interchange is HLO **text** (not serialized HloModuleProto): jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published `xla` crate binds) rejects; the text parser reassigns ids.

Exported artifacts (see also the manifest):

  teacher_train_step   — Adam + CE step for the FP teacher.
  student_train_step   — QAKD step for the binary (LittleBit) student.
  student_fp_train_step— QAKD step for the Tiny-Rank FP student (Strategy A).
  teacher_eval / student_eval / student_fp_eval — held-out CE.
  student_infer        — student logits through the L1 Pallas tri-scale
                         kernel (the deployed inference graph).
  littlebit_layer      — standalone fused tri-scale layer (quickstart/serving
                         micro-benchmarks).

Constraint honoured throughout: exported graphs contain no jnp.linalg.*
(those lower to lapack custom-calls that only jaxlib's runtime registers —
the rust PJRT client cannot resolve them). The SVD/ITQ initialization
pipeline therefore runs natively in rust (`littlebit::compress`); Python
keeps an equivalent implementation for cross-validation in pytest.

Run: ``python -m compile.aot --out-dir ../artifacts`` (idempotent; the
Makefile skips it when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.tri_scale import mxu_utilization_estimate, tri_scale_matmul, vmem_bytes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def spec_structs(spec):
    return [f32(shape) for _, shape in spec]


def lower_train_steps(cfg: M.ModelConfig):
    """Build the (fn, example-args) pairs for every artifact."""
    t_spec = M.teacher_param_spec(cfg)
    s_cfg = cfg
    s_spec = M.student_param_spec(s_cfg)
    fp_cfg = dataclasses.replace(cfg, fp_latent=True)
    fp_spec = M.student_param_spec(fp_cfg)

    tok = i32((cfg.batch, cfg.seq + 1))
    scalar = f32(())

    nt = len(t_spec)
    ns = len(s_spec)
    nf = len(fp_spec)

    def teacher_train(*args):
        p = list(args[:nt])
        m = list(args[nt : 2 * nt])
        v = list(args[2 * nt : 3 * nt])
        step, tokens, lr = args[3 * nt], args[3 * nt + 1], args[3 * nt + 2]
        p2, m2, v2, loss = M.teacher_train_step(cfg, p, m, v, step, tokens, lr)
        return tuple(p2) + tuple(m2) + tuple(v2) + (loss,)

    teacher_train_args = (
        spec_structs(t_spec) * 3 + [scalar, tok, scalar]
    )

    def make_student_train(scfg, sspec, n):
        def student_train(*args):
            sp = list(args[:n])
            tp = list(args[n : n + nt])
            m = list(args[n + nt : 2 * n + nt])
            v = list(args[2 * n + nt : 3 * n + nt])
            step = args[3 * n + nt]
            tokens = args[3 * n + nt + 1]
            lr = args[3 * n + nt + 2]
            p2, m2, v2, loss, flips = M.student_train_step(
                scfg, sp, tp, m, v, step, tokens, lr
            )
            return tuple(p2) + tuple(m2) + tuple(v2) + (loss, flips)

        args = (
            spec_structs(sspec)
            + spec_structs(t_spec)
            + spec_structs(sspec) * 2
            + [scalar, tok, scalar]
        )
        return student_train, args

    student_train, student_train_args = make_student_train(s_cfg, s_spec, ns)
    fp_train, fp_train_args = make_student_train(fp_cfg, fp_spec, nf)

    def teacher_eval(*args):
        p = list(args[:nt])
        return (M.eval_loss(cfg, p, args[nt], student=False),)

    def student_eval(*args):
        p = list(args[:ns])
        return (M.eval_loss(s_cfg, p, args[ns], student=True),)

    def fp_eval(*args):
        p = list(args[:nf])
        return (M.eval_loss(fp_cfg, p, args[nf], student=True),)

    def student_infer(*args):
        p = list(args[:ns])
        tokens = args[ns]
        return (M.student_logits(s_cfg, p, tokens, use_pallas=True),)

    infer_tok = i32((cfg.batch, cfg.seq))

    artifacts = {
        "teacher_train_step": (teacher_train, teacher_train_args),
        "student_train_step": (student_train, student_train_args),
        "student_fp_train_step": (fp_train, fp_train_args),
        "teacher_eval": (teacher_eval, spec_structs(t_spec) + [tok]),
        "student_eval": (student_eval, spec_structs(s_spec) + [tok]),
        "student_fp_eval": (fp_eval, spec_structs(fp_spec) + [tok]),
        "student_infer": (student_infer, spec_structs(s_spec) + [infer_tok]),
    }
    return artifacts, t_spec, s_spec, fp_spec


def lower_layer_kernel(d_in: int, d_out: int, r: int, batch: int):
    """Standalone fused tri-scale layer (Pallas) for serving benches."""

    def layer(x, u_b, v_b, h, l, g):
        return (tri_scale_matmul(x, u_b, v_b, h, l, g),)

    args = [
        f32((batch, d_in)),
        f32((d_out, r)),
        f32((d_in, r)),
        f32((d_out,)),
        f32((r,)),
        f32((d_in,)),
    ]
    return layer, args


def preset(name: str) -> M.ModelConfig:
    if name == "tiny":  # CI-fast config
        return M.ModelConfig(
            vocab=256, d_model=64, n_layers=2, n_heads=2, d_ff=172,
            seq=32, batch=4, bpp=1.0,
        )
    if name == "small":  # the recorded e2e run (1-core CPU budget)
        return M.ModelConfig(
            vocab=512, d_model=128, n_layers=4, n_heads=4, d_ff=344,
            seq=64, batch=8, bpp=1.0,
        )
    if name == "base":  # larger config for multi-core machines
        return M.ModelConfig(
            vocab=2048, d_model=384, n_layers=8, n_heads=6, d_ff=1024,
            seq=128, batch=8, bpp=1.0,
        )
    raise SystemExit(f"unknown preset {name!r}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=["tiny", "small", "base"])
    ap.add_argument("--bpp", type=float, default=None,
                    help="override student bit budget")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = preset(args.preset)
    if args.bpp is not None:
        cfg = dataclasses.replace(cfg, bpp=args.bpp)

    os.makedirs(args.out_dir, exist_ok=True)
    artifacts, t_spec, s_spec, fp_spec = lower_train_steps(cfg)

    manifest = {
        "config": dataclasses.asdict(cfg),
        "preset": args.preset,
        "teacher_spec": [[n, list(s)] for n, s in t_spec],
        "student_spec": [[n, list(s)] for n, s in s_spec],
        "student_fp_spec": [[n, list(s)] for n, s in fp_spec],
        "artifacts": {},
    }

    for name, (fn, example_args) in artifacts.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "path": f"{name}.hlo.txt",
            "num_inputs": len(example_args),
            "input_shapes": [
                [str(a.dtype), list(a.shape)] for a in example_args
            ],
        }
        print(f"lowered {name}: {len(text)} chars, {len(example_args)} inputs")

    # Standalone layer kernel at a serving-relevant shape.
    d_in = d_out = 1024
    r = 64
    layer_fn, layer_args = lower_layer_kernel(d_in, d_out, r, batch=4)
    lowered = jax.jit(layer_fn).lower(*layer_args)
    with open(os.path.join(args.out_dir, "littlebit_layer.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"]["littlebit_layer"] = {
        "path": "littlebit_layer.hlo.txt",
        "num_inputs": len(layer_args),
        "input_shapes": [[str(a.dtype), list(a.shape)] for a in layer_args],
        "shape": {"d_in": d_in, "d_out": d_out, "r": r, "batch": 4},
    }

    # Teacher initialization (build-time): raw f32 little-endian .bin blobs.
    key = jax.random.PRNGKey(args.seed)
    params = M.init_teacher(cfg, key)
    bin_dir = os.path.join(args.out_dir, "params")
    os.makedirs(bin_dir, exist_ok=True)
    import numpy as np

    for (name, shape), arr in zip(t_spec, params):
        safe = name.replace(".", "_")
        np.asarray(arr, dtype="<f4").tofile(os.path.join(bin_dir, f"{safe}.bin"))
    manifest["teacher_init_dir"] = "params"

    # L1 perf-model estimates (§Perf, recorded in EXPERIMENTS.md).
    manifest["l1_perf_estimates"] = {
        "layer_shape": {"d_in": d_in, "d_out": d_out, "r": r},
        "vmem_bytes": vmem_bytes(d_in, d_out, r),
        "mxu_utilization": mxu_utilization_estimate(d_in, d_out, r),
    }

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest written: {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
