"""L2: JAX transformer LM with LittleBit tri-scale linear layers + QAKD.

Defines (a) a standard FP decoder-only transformer (the *teacher*), (b) the
same architecture with every body linear replaced by the residual LittleBit
tri-scale factorization trained with straight-through estimation (the
*student*), and (c) the quantization-aware knowledge-distillation train
step used by both the paper's protocol (§2.1) and our e2e run.

Everything here runs at build time only: ``aot.py`` lowers these functions
to HLO text once; the rust L3 coordinator then drives training/eval through
PJRT without Python.

Parameters travel as flat lists of arrays (deterministic order defined by
``param_spec``) because the rust runtime feeds positional literals.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.tri_scale import tri_scale_matmul


# --------------------------------------------------------------------------
# Configuration
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 344           # SwiGLU width (~8/3 · d_model)
    seq: int = 64
    batch: int = 8
    # Student compression settings.
    bpp: float = 1.0
    residual_paths: int = 2
    # Tiny-Rank FP16 ablation variant (Strategy A): latents used directly
    # (no sign/STE), rank budgeted at 16 bits per factor entry.
    fp_latent: bool = False
    # Distillation mix: loss = kd_alpha·KL(teacher‖student) + (1−kd_alpha)·CE.
    kd_alpha: float = 0.5
    kd_temperature: float = 2.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def rank_for_budget(self, d_out: int, d_in: int) -> int:
        """Eq. 26 with the residual path count folded in (App. H); the FP
        variant pays 16 bits per latent entry instead of 1."""
        n = d_in * d_out
        paths = self.residual_paths
        if self.fp_latent:
            num = self.bpp * n
            den = 16.0 * paths * (d_in + d_out)
        else:
            num = self.bpp * n - 16.0 * paths * (d_in + d_out)
            den = paths * (d_in + d_out + 16)
        r = max(int(math.floor(num / den)), 1)
        # A factorization rank above min(d) is meaningless (and the SVD
        # truncation silently caps there) — clamp so specs stay consistent.
        return min(r, min(d_in, d_out))


# Body projections of one block: (name, d_out_fn, d_in_fn).
_PROJS = [
    ("q", lambda c: c.d_model, lambda c: c.d_model),
    ("k", lambda c: c.d_model, lambda c: c.d_model),
    ("v", lambda c: c.d_model, lambda c: c.d_model),
    ("o", lambda c: c.d_model, lambda c: c.d_model),
    ("gate", lambda c: c.d_ff, lambda c: c.d_model),
    ("up", lambda c: c.d_ff, lambda c: c.d_model),
    ("down", lambda c: c.d_model, lambda c: c.d_ff),
]


# --------------------------------------------------------------------------
# Parameter specs — the contract with the rust runtime
# --------------------------------------------------------------------------


def teacher_param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list of teacher parameters."""
    spec = [("embed", (cfg.vocab, cfg.d_model))]
    for b in range(cfg.n_layers):
        spec.append((f"b{b}.ln1", (cfg.d_model,)))
        spec.append((f"b{b}.ln2", (cfg.d_model,)))
        for name, fo, fi in _PROJS:
            spec.append((f"b{b}.{name}", (fo(cfg), fi(cfg))))
    spec.append(("ln_f", (cfg.d_model,)))
    spec.append(("head", (cfg.vocab, cfg.d_model)))
    return spec


def student_param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Student: embeddings/norms/head stay FP (paper convention); every body
    linear becomes `residual_paths` tri-scale factor sets."""
    spec = [("embed", (cfg.vocab, cfg.d_model))]
    for b in range(cfg.n_layers):
        spec.append((f"b{b}.ln1", (cfg.d_model,)))
        spec.append((f"b{b}.ln2", (cfg.d_model,)))
        for name, fo, fi in _PROJS:
            d_out, d_in = fo(cfg), fi(cfg)
            r = cfg.rank_for_budget(d_out, d_in)
            for p in range(cfg.residual_paths):
                base = f"b{b}.{name}.p{p}"
                spec.append((f"{base}.lat_u", (d_out, r)))
                spec.append((f"{base}.lat_v", (d_in, r)))
                spec.append((f"{base}.h", (d_out,)))
                spec.append((f"{base}.l", (r,)))
                spec.append((f"{base}.g", (d_in,)))
    spec.append(("ln_f", (cfg.d_model,)))
    spec.append(("head", (cfg.vocab, cfg.d_model)))
    return spec


# --------------------------------------------------------------------------
# Shared transformer pieces
# --------------------------------------------------------------------------


def _rmsnorm(x, w):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * w


def _rope(x, positions):
    """Rotary position embedding over the last dim (pairs)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    angles = positions[:, None] * freqs[None, :]  # [S, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(q, k, v, cfg: ModelConfig):
    """Causal MHA. q,k,v: [B, S, d_model]."""
    b, s, _ = q.shape
    hd = cfg.head_dim
    pos = jnp.arange(s, dtype=jnp.float32)

    def split(t):
        return t.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split(q), split(k), split(v)
    q = _rope(q, pos)
    k = _rope(k, pos)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    return out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * hd)


def _block(x, params, linear_fn, cfg: ModelConfig):
    """One decoder block. `linear_fn(name, x2d) -> y2d` dispatches to the
    teacher dense weights or the student tri-scale layers."""
    b, s, d = x.shape
    h = _rmsnorm(x, params["ln1"])
    h2 = h.reshape(b * s, d)
    q = linear_fn("q", h2).reshape(b, s, -1)
    k = linear_fn("k", h2).reshape(b, s, -1)
    v = linear_fn("v", h2).reshape(b, s, -1)
    att = _attention(q, k, v, cfg)
    x = x + linear_fn("o", att.reshape(b * s, -1)).reshape(b, s, d)

    h = _rmsnorm(x, params["ln2"])
    h2 = h.reshape(b * s, d)
    gate = linear_fn("gate", h2)
    up = linear_fn("up", h2)
    ff = jax.nn.silu(gate) * up
    x = x + linear_fn("down", ff).reshape(b, s, d)
    return x


# --------------------------------------------------------------------------
# Teacher (FP) model
# --------------------------------------------------------------------------


def _unflatten(spec, flat):
    assert len(spec) == len(flat), f"{len(spec)} vs {len(flat)}"
    return {name: arr for (name, _), arr in zip(spec, flat)}


def teacher_logits(cfg: ModelConfig, flat_params, tokens):
    """tokens: [B, S] int32 → logits [B, S, vocab]."""
    p = _unflatten(teacher_param_spec(cfg), flat_params)
    x = p["embed"][tokens]
    for b in range(cfg.n_layers):
        blk = {
            "ln1": p[f"b{b}.ln1"],
            "ln2": p[f"b{b}.ln2"],
        }

        def linear(name, x2d, b=b):
            return x2d @ p[f"b{b}.{name}"].T

        x = _block(x, blk, linear, cfg)
    x = _rmsnorm(x, p["ln_f"])
    return x @ p["head"].T


# --------------------------------------------------------------------------
# Student (LittleBit tri-scale, STE) model
# --------------------------------------------------------------------------


def _sign_ste(x):
    """sign with straight-through gradient (Bengio et al., 2013)."""
    s = jnp.where(x < 0, -1.0, 1.0).astype(x.dtype)
    return x + jax.lax.stop_gradient(s - x)


def student_logits(cfg: ModelConfig, flat_params, tokens, use_pallas: bool = False):
    """Student forward. ``use_pallas=True`` routes the tri-scale matmul
    through the L1 Pallas kernel (exported inference graph); training uses
    the jnp oracle (identical numerics, pinned by python/tests)."""
    p = _unflatten(student_param_spec(cfg), flat_params)
    x = p["embed"][tokens]
    for b in range(cfg.n_layers):
        blk = {"ln1": p[f"b{b}.ln1"], "ln2": p[f"b{b}.ln2"]}

        def linear(name, x2d, b=b):
            out = None
            for path in range(cfg.residual_paths):
                base = f"b{b}.{name}.p{path}"
                lat_u, lat_v = p[f"{base}.lat_u"], p[f"{base}.lat_v"]
                if cfg.fp_latent:
                    u_b, v_b = lat_u, lat_v  # Strategy A: FP latents as-is
                else:
                    u_b, v_b = _sign_ste(lat_u), _sign_ste(lat_v)
                h, l, g = p[f"{base}.h"], p[f"{base}.l"], p[f"{base}.g"]
                if use_pallas:
                    y = tri_scale_matmul(x2d, u_b, v_b, h, l, g)
                else:
                    y = ref.tri_scale_matmul_ref(x2d, u_b, v_b, h, l, g)
                out = y if out is None else out + y
            return out

        x = _block(x, blk, linear, cfg)
    x = _rmsnorm(x, p["ln_f"])
    return x @ p["head"].T


# --------------------------------------------------------------------------
# Losses, metrics
# --------------------------------------------------------------------------


def next_token_ce(logits, tokens_full):
    """Cross-entropy of logits[:, :-1] against tokens[:, 1:]... callers pass
    tokens block [B, S+1] and logits over [B, S]; here logits are computed
    on tokens_full[:, :-1]."""
    labels = tokens_full[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def kd_loss(student_logits_, teacher_logits_, temperature):
    """KL(teacher ‖ student) with temperature scaling."""
    t = temperature
    pt = jax.nn.softmax(teacher_logits_ / t, axis=-1)
    log_ps = jax.nn.log_softmax(student_logits_ / t, axis=-1)
    log_pt = jax.nn.log_softmax(teacher_logits_ / t, axis=-1)
    return jnp.mean(jnp.sum(pt * (log_pt - log_ps), axis=-1)) * t * t


def sign_flip_count(old_flat, new_flat, spec):
    """Number of binary latent entries whose sign changed (Fig. 8 metric),
    plus the total latent count."""
    flips = jnp.array(0.0)
    total = 0
    for (name, shape), old, new in zip(spec, old_flat, new_flat):
        if ".lat_" in name:
            flips = flips + jnp.sum((old < 0) != (new < 0))
            total += math.prod(shape)
    return flips, total


# --------------------------------------------------------------------------
# Adam + train steps
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class AdamConfig:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8


def adam_update(params, grads, m, v, step, lr, ac: AdamConfig = AdamConfig()):
    new_p, new_m, new_v = [], [], []
    t = step + 1.0
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ac.b1 * mi + (1 - ac.b1) * g
        vi = ac.b2 * vi + (1 - ac.b2) * g * g
        mhat = mi / (1 - ac.b1**t)
        vhat = vi / (1 - ac.b2**t)
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + ac.eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def teacher_train_step(cfg: ModelConfig, params, m, v, step, tokens, lr):
    """One Adam step of plain next-token CE for the teacher.
    Returns (params', m', v', loss)."""

    def loss_fn(ps):
        logits = teacher_logits(cfg, ps, tokens[:, :-1])
        return next_token_ce(logits, tokens)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, m, v = adam_update(params, grads, m, v, step, lr)
    return params, m, v, loss


def student_train_step(cfg: ModelConfig, s_params, t_params, m, v, step, tokens, lr):
    """One QAKD step (§2.1 protocol): CE + KD against the frozen teacher.
    Returns (s_params', m', v', loss, flips)."""

    t_logits = jax.lax.stop_gradient(teacher_logits(cfg, t_params, tokens[:, :-1]))

    def loss_fn(ps):
        s_logits = student_logits(cfg, ps, tokens[:, :-1])
        ce = next_token_ce(s_logits, tokens)
        kd = kd_loss(s_logits, t_logits, cfg.kd_temperature)
        return cfg.kd_alpha * kd + (1 - cfg.kd_alpha) * ce

    loss, grads = jax.value_and_grad(loss_fn)(s_params)
    new_params, m, v = adam_update(s_params, grads, m, v, step, lr)
    flips, _total = sign_flip_count(s_params, new_params, student_param_spec(cfg))
    return new_params, m, v, loss, flips


def eval_loss(cfg: ModelConfig, flat_params, tokens, student: bool):
    """Mean next-token CE (exp → PPL) for held-out evaluation."""
    logits = (
        student_logits(cfg, flat_params, tokens[:, :-1])
        if student
        else teacher_logits(cfg, flat_params, tokens[:, :-1])
    )
    return next_token_ce(logits, tokens)


# --------------------------------------------------------------------------
# Initialization (build-time; exported as .bin for the rust driver)
# --------------------------------------------------------------------------


def init_teacher(cfg: ModelConfig, key) -> List[jnp.ndarray]:
    out = []
    for name, shape in teacher_param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            out.append(jnp.ones(shape, jnp.float32))
        elif name in ("embed", "head"):
            out.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
        else:
            fan_in = shape[1]
            out.append(
                jax.random.normal(sub, shape, jnp.float32) / math.sqrt(fan_in)
            )
    return out


def zeros_like_params(spec) -> List[jnp.ndarray]:
    return [jnp.zeros(shape, jnp.float32) for _, shape in spec]


# --------------------------------------------------------------------------
# Student initialization from a trained teacher (Fig. 2 pipeline, build time)
# --------------------------------------------------------------------------


def _truncated_svd_factors(w, r):
    """Û = U√Σ, V̂ = V√Σ at rank r (Alg. 2 steps 1-2)."""
    u, s, vh = jnp.linalg.svd(w, full_matrices=False)
    sq = jnp.sqrt(s[:r])
    return u[:, :r] * sq, vh[:r, :].T * sq


def _dual_svid_scales(u_t, v_t):
    """Rank-1 magnitude decomposition → (h, l, g) (Alg. 2 step 3)."""
    h, l_u = ref.rank_one_decompose_ref(jnp.abs(u_t))
    g, l_v = ref.rank_one_decompose_ref(jnp.abs(v_t))
    return h, l_u * l_v, g


def compress_layer_init(w, r, strategy: str, key, itq_iters: int = 50,
                        n_paths: int = 2, fp_latent: bool = False):
    """Initialize `n_paths` residual tri-scale parameter sets for weight `w`.

    strategy ∈ {"standard", "rotation", "itq"} — the Table 3 axis.
    Returns a list of (lat_u, lat_v, h, l, g) per path.
    """
    paths = []
    target = w
    for _ in range(n_paths):
        u_t, v_t = _truncated_svd_factors(target, r)
        if not fp_latent and strategy != "standard":
            key, sub = jax.random.split(key)
            g0 = jax.random.normal(sub, (r, r), jnp.float32)
            rot0, _ = jnp.linalg.qr(g0)
            if strategy == "rotation":
                rot = rot0
            elif strategy == "itq":
                z = jnp.concatenate([u_t, v_t], axis=0)
                rot = ref.joint_itq_ref(z, rot0, itq_iters)
            else:
                raise ValueError(f"unknown strategy {strategy!r}")
            u_t, v_t = u_t @ rot, v_t @ rot
        if fp_latent:
            ones_h = jnp.ones((w.shape[0],), jnp.float32)
            ones_l = jnp.ones((r,), jnp.float32)
            ones_g = jnp.ones((w.shape[1],), jnp.float32)
            paths.append((u_t, v_t, ones_h, ones_l, ones_g))
            recon = u_t @ v_t.T
        else:
            h, l, g = _dual_svid_scales(u_t, v_t)
            paths.append((u_t, v_t, h, l, g))
            u_b = jnp.where(u_t < 0, -1.0, 1.0)
            v_b = jnp.where(v_t < 0, -1.0, 1.0)
            recon = ((u_b * h[:, None]) * l[None, :]) @ (v_b * g[:, None]).T
        target = target - recon
    return paths


def init_student_from_teacher(cfg: ModelConfig, teacher_flat, strategy: str,
                              key, itq_iters: int = 50) -> List[jnp.ndarray]:
    """Build the full student parameter list by compressing every teacher
    body linear; embeddings/norms/head are copied (kept FP)."""
    t = _unflatten(teacher_param_spec(cfg), teacher_flat)
    out: List[jnp.ndarray] = []
    for name, shape in student_param_spec(cfg):
        if ".p" not in name:
            out.append(t[name])
    # Re-walk in spec order, emitting tri-scale params lazily per layer.
    out = []
    cache = {}
    for name, shape in student_param_spec(cfg):
        if ".p" not in name:
            out.append(t[name])
            continue
        layer, rest = name.split(".p", 1)
        pidx, field_name = rest.split(".", 1)
        pidx = int(pidx)
        if layer not in cache:
            w = t[layer]
            r = cfg.rank_for_budget(w.shape[0], w.shape[1])
            key, sub = jax.random.split(key)
            cache[layer] = compress_layer_init(
                w, r, strategy, sub, itq_iters, cfg.residual_paths,
                cfg.fp_latent,
            )
        lat_u, lat_v, h, l, g = cache[layer][pidx]
        out.append({"lat_u": lat_u, "lat_v": lat_v, "h": h, "l": l, "g": g}[field_name])
    return out
