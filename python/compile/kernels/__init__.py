"""L1 Pallas kernels (build-time only) + their pure-jnp oracles."""

from . import binarize, itq_step, ref, tri_scale  # noqa: F401
