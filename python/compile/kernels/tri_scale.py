"""L1 Pallas kernel: fused tri-scale low-rank binary matmul (Eq. 1).

The paper's inference hot-spot. On GPU the authors fuse the
scale-binary-scale pipeline into a custom CUDA bit-GEMV; the TPU-style
mapping (DESIGN.md §Hardware-Adaptation) tiles the two MXU matmuls through
VMEM with the three VPU element-wise scales fused around them:

    y[tile] = (((x·g) @ V_b[tile]) · l) @ U_bᵀ[tile] · h[tile]

Grid: one program per (batch-tile, d_out-tile). The latent dimension r is
small by construction (sub-1-bit budgets ⇒ r ≤ ~256), so the whole latent
panel V_b (d_in×r) rides in VMEM while U_b streams per output tile.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
under the rust runtime. Real-TPU perf is *estimated* from the VMEM/MXU
model in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output tile along d_out. 128 matches the MXU lane width.
TILE_OUT = 128
# Batch (rows of x) tile.
TILE_B = 8


def _kernel(xg_ref, vb_ref, l_ref, ub_ref, h_ref, o_ref):
    """One (batch-tile, out-tile) program.

    xg_ref: [TILE_B, d_in]   — pre-scaled activations (x*g).
    vb_ref: [d_in, r]        — full V_b panel (resident).
    l_ref:  [r]              — central scale.
    ub_ref: [TILE_OUT, r]    — U_b rows for this output tile.
    h_ref:  [TILE_OUT]       — row scales for this tile.
    o_ref:  [TILE_B, TILE_OUT]
    """
    latent = jnp.dot(xg_ref[...], vb_ref[...])  # [TILE_B, r] — MXU
    latent = latent * l_ref[...]                # VPU
    out = jnp.dot(latent, ub_ref[...].T)        # [TILE_B, TILE_OUT] — MXU
    o_ref[...] = out * h_ref[...]               # VPU


@functools.partial(jax.jit, static_argnames=())
def tri_scale_matmul(x, u_b, v_b, h, l, g):
    """Fused Eq. 1 forward via pallas_call. Shapes as in ref.py; ``x`` may
    be [B, d_in] or [d_in]."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None, :]
    b, d_in = x.shape
    d_out, r = u_b.shape

    # Pad batch and d_out to tile multiples (pallas BlockSpec needs exact
    # tiling; padding is sliced away afterwards).
    pb = (-b) % TILE_B
    po = (-d_out) % TILE_OUT
    xg = x * g
    if pb:
        xg = jnp.pad(xg, ((0, pb), (0, 0)))
    u_bp = jnp.pad(u_b, ((0, po), (0, 0))) if po else u_b
    hp = jnp.pad(h, (0, po)) if po else h

    grid = (xg.shape[0] // TILE_B, u_bp.shape[0] // TILE_OUT)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((d_in, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r,), lambda i, j: (0,)),
            pl.BlockSpec((TILE_OUT, r), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_OUT,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((TILE_B, TILE_OUT), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xg.shape[0], u_bp.shape[0]), x.dtype),
        interpret=True,
    )(xg, v_b, l, u_bp, hp)

    out = out[:b, :d_out]
    return out[0] if squeeze else out


def vmem_bytes(d_in: int, d_out: int, r: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency of one program instance — the §Perf L1
    metric. V_b panel + U_b tile + x tile + latent + output tile."""
    return dtype_bytes * (
        d_in * r          # V_b panel
        + TILE_OUT * r    # U_b tile
        + TILE_B * d_in   # xg tile
        + TILE_B * r      # latent
        + TILE_B * TILE_OUT
        + r + TILE_OUT    # l, h slices
    )


def mxu_utilization_estimate(d_in: int, d_out: int, r: int) -> float:
    """Fraction of MXU issue slots doing useful work, assuming 128×128
    systolic tiles: both matmuls have inner dim r; utilization ≈ r/128
    capped at 1 (§Perf L1 estimate, recorded in EXPERIMENTS.md)."""
    return min(r / 128.0, 1.0)
