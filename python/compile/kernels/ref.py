"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in this package has its semantics pinned here in plain
``jax.numpy``; ``python/tests/`` asserts allclose between kernel and oracle
across a hypothesis-driven sweep of shapes/dtypes. The oracles are also the
implementation used inside the *training* graph (mathematically identical,
cheaper to trace), while the Pallas kernels power the exported inference
graphs — both lower into the same HLO artifact set (see aot.py).
"""

from __future__ import annotations

import jax.numpy as jnp


def tri_scale_matmul_ref(x, u_b, v_b, h, l, g):
    """Eq. 1 forward: ``y = (((x*g) @ V_b) * l) @ U_bᵀ * h``.

    Args:
      x:   [..., d_in] activations.
      u_b: [d_out, r] binary (±1) factor.
      v_b: [d_in, r] binary (±1) factor.
      h:   [d_out] row scale.
      l:   [r] central latent scale.
      g:   [d_in] column scale.

    Returns: [..., d_out].
    """
    latent = (x * g) @ v_b  # [..., r]
    latent = latent * l
    return (latent @ u_b.T) * h


def binarize_ref(u):
    """Optimal row-wise binarization (Lemma 4.2): returns (signs, alpha).

    ``u``: [n, r]. signs: sign(u) with sign(0) := +1; alpha[i] = ‖u_i‖₁/r.
    """
    signs = jnp.where(u < 0, -1.0, 1.0).astype(u.dtype)
    alpha = jnp.mean(jnp.abs(u), axis=-1)
    return signs, alpha


def local_distortion_ref(u):
    """λ(u) per row: 1 − (‖u‖₁/‖u‖₂)²/r (Lemma 4.2). Zero rows give λ=0."""
    l1 = jnp.sum(jnp.abs(u), axis=-1)
    l2sq = jnp.sum(u * u, axis=-1)
    r = u.shape[-1]
    lam = 1.0 - (l1 * l1) / (r * jnp.maximum(l2sq, 1e-30))
    return jnp.where(l2sq > 0, jnp.maximum(lam, 0.0), 0.0)


def itq_sign_project_ref(z, rot):
    """Joint-ITQ step A (Alg. 1 line 8): B = sign(Z R)."""
    zr = z @ rot
    return jnp.where(zr < 0, -1.0, 1.0).astype(z.dtype)


def itq_procrustes_ref(b, z):
    """Joint-ITQ step B (Alg. 1 lines 9-10): R = Ψ Φᵀ from SVD(BᵀZ)=ΦΩΨᵀ."""
    m = b.T @ z
    phi, _, psi_t = jnp.linalg.svd(m, full_matrices=False)
    return psi_t.T @ phi.T


def joint_itq_ref(z, rot0, iters):
    """Full Joint-ITQ loop (Alg. 1) in jnp, for build-time verification of
    the rust solver and for the exported itq_step artifact."""
    rot = rot0
    for _ in range(iters):
        b = itq_sign_project_ref(z, rot)
        rot = itq_procrustes_ref(b, z)
    return rot


def rank_one_decompose_ref(x):
    """Rank-1 magnitude decomposition (Listing 1): X ≈ u vᵀ, u,v ≥ 0."""
    u, s, vh = jnp.linalg.svd(x, full_matrices=False)
    sq = jnp.sqrt(s[0])
    uvec = u[:, 0] * sq
    vvec = vh[0, :] * sq
    flip = jnp.where(jnp.sum(uvec) < 0, -1.0, 1.0)
    return jnp.maximum(uvec * flip, 0.0), jnp.maximum(vvec * flip, 0.0)
