"""L1 Pallas kernel: the Joint-ITQ code-update step (Alg. 1 line 8).

One ITQ iteration = (A) ``B = sign(Z R)`` — a tall-matmul + sign, tiled
here — and (B) the r×r Procrustes solve, which is a small SVD left to
XLA (jnp.linalg.svd) at the L2 level: r ≤ ~256, so step A dominates the
work at (d_in+d_out)·r² FLOPs vs O(r³).

The kernel fuses the matmul with the sign projection and also emits the
per-tile L1 mass Σ|ZR| — the monotone objective of App. A.2 — so the L2
loop gets its convergence trace for free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 128


def _kernel(z_ref, r_ref, b_ref, mass_ref):
    zr = jnp.dot(z_ref[...], r_ref[...])  # [TILE_ROWS, r] — MXU
    b_ref[...] = jnp.where(zr < 0, -1.0, 1.0).astype(zr.dtype)
    mass_ref[...] = jnp.sum(jnp.abs(zr), axis=-1)


def sign_project(z, rot):
    """``B = sign(Z @ rot)`` plus per-row L1 mass. z: [n, r], rot: [r, r]."""
    n, r = z.shape
    pad = (-n) % TILE_ROWS
    zp = jnp.pad(z, ((0, pad), (0, 0))) if pad else z
    grid = (zp.shape[0] // TILE_ROWS,)
    b, mass = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_ROWS, r), lambda i: (i, 0)),
            pl.BlockSpec((r, r), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_ROWS, r), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((zp.shape[0], r), z.dtype),
            jax.ShapeDtypeStruct((zp.shape[0],), z.dtype),
        ],
        interpret=True,
    )(zp, rot)
    return b[:n], jnp.sum(mass[:n])


def itq_iteration(z, rot):
    """One full Joint-ITQ alternation: Pallas step A + jnp Procrustes step B.
    Returns (new_rot, l1_mass)."""
    b, mass = sign_project(z, rot)
    m = b.T @ z
    phi, _, psi_t = jnp.linalg.svd(m, full_matrices=False)
    return psi_t.T @ phi.T, mass
