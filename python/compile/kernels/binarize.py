"""L1 Pallas kernel: row-wise optimal binarization (Lemma 4.2).

Computes ``signs = sign(U)`` and the optimal per-row scale
``alpha_i = ‖u_i‖₁ / r`` in one pass. Used by the exported compression
graph (quantize-layer artifact) and — with straight-through gradients at
the L2 level — inside QAT.

Grid: one program per row-tile; each program reduces its rows' |u| on the
VPU and emits signs + alpha. interpret=True (see tri_scale.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 64


def _kernel(u_ref, signs_ref, alpha_ref):
    u = u_ref[...]
    signs_ref[...] = jnp.where(u < 0, -1.0, 1.0).astype(u.dtype)
    alpha_ref[...] = jnp.mean(jnp.abs(u), axis=-1)


def binarize(u):
    """Row-wise sign + optimal alpha. ``u``: [n, r] → ([n, r], [n])."""
    n, r = u.shape
    pad = (-n) % TILE_ROWS
    up = jnp.pad(u, ((0, pad), (0, 0))) if pad else u
    grid = (up.shape[0] // TILE_ROWS,)
    signs, alpha = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_ROWS, r), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((TILE_ROWS, r), lambda i: (i, 0)),
            pl.BlockSpec((TILE_ROWS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((up.shape[0], r), u.dtype),
            jax.ShapeDtypeStruct((up.shape[0],), u.dtype),
        ],
        interpret=True,
    )(up)
    return signs[:n], alpha[:n]
