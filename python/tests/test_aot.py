"""AOT lowering contract tests.

Guards the properties the rust runtime depends on:
  * HLO text parses and contains no custom-calls (the standalone PJRT
    client cannot resolve lapack/jaxlib targets);
  * artifact arities match the manifest;
  * the tiny preset lowers end-to-end.
"""

import dataclasses
import json

import jax
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def tiny_cfg():
    return aot.preset("tiny")


@pytest.fixture(scope="module")
def lowered(tiny_cfg):
    artifacts, t_spec, s_spec, fp_spec = aot.lower_train_steps(tiny_cfg)
    out = {}
    for name, (fn, args) in artifacts.items():
        low = jax.jit(fn).lower(*args)
        out[name] = (aot.to_hlo_text(low), len(args))
    return out


def test_all_artifacts_lower(lowered):
    assert set(lowered) == {
        "teacher_train_step",
        "student_train_step",
        "student_fp_train_step",
        "teacher_eval",
        "student_eval",
        "student_fp_eval",
        "student_infer",
    }


def test_no_custom_calls(lowered):
    for name, (text, _) in lowered.items():
        assert "custom-call" not in text, f"{name} contains a custom-call"
        assert "CustomCall" not in text, f"{name} contains a CustomCall"


def test_hlo_text_is_parseable_shape(lowered):
    for name, (text, _) in lowered.items():
        assert text.startswith("HloModule"), f"{name} missing HloModule header"
        assert "ENTRY" in text, f"{name} missing entry computation"


def test_train_step_arity(tiny_cfg, lowered):
    nt = len(M.teacher_param_spec(tiny_cfg))
    ns = len(M.student_param_spec(tiny_cfg))
    assert lowered["teacher_train_step"][1] == 3 * nt + 3
    assert lowered["student_train_step"][1] == 3 * ns + nt + 3


def test_layer_kernel_lowering():
    fn, args = aot.lower_layer_kernel(128, 256, 16, batch=2)
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "custom-call" not in text
    assert text.startswith("HloModule")


def test_presets_are_distinct():
    tiny, small, base = aot.preset("tiny"), aot.preset("small"), aot.preset("base")
    assert tiny.d_model < small.d_model < base.d_model
    with pytest.raises(SystemExit):
        aot.preset("huge")


def test_bpp_override_changes_ranks(tiny_cfg):
    lo = dataclasses.replace(tiny_cfg, bpp=0.4)
    hi = dataclasses.replace(tiny_cfg, bpp=2.0)
    assert lo.rank_for_budget(172, 64) < hi.rank_for_budget(172, 64)
