"""L2 model tests: teacher/student forward, QAKD training dynamics,
compression-initialized students, STE gradient flow."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


CFG = M.ModelConfig(
    vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=88, seq=16, batch=2,
    bpp=2.0,
)


@pytest.fixture(scope="module")
def teacher():
    return M.init_teacher(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(jax.random.PRNGKey(1), (CFG.batch, CFG.seq + 1), 0, CFG.vocab)


def test_teacher_logits_shape(teacher, tokens):
    logits = M.teacher_logits(CFG, teacher, tokens[:, :-1])
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_param_specs_consistent():
    t = M.teacher_param_spec(CFG)
    s = M.student_param_spec(CFG)
    assert t[0][0] == "embed" and t[-1][0] == "head"
    # Student has 5 tensors per path per projection.
    n_tri = sum(1 for n, _ in s if ".p" in n)
    assert n_tri == CFG.n_layers * 7 * CFG.residual_paths * 5


def test_rank_budget_matches_eq26():
    d_out, d_in = 88, 32
    r = CFG.rank_for_budget(d_out, d_in)
    n = d_in * d_out
    bits = 2 * r * (d_in + d_out + 16) + 32 * (d_in + d_out)
    assert bits <= CFG.bpp * n
    bits_next = 2 * (r + 1) * (d_in + d_out + 16) + 32 * (d_in + d_out)
    assert bits_next > CFG.bpp * n


def test_student_init_reconstructs_teacher(teacher, tokens):
    """ITQ-initialized student logits should track the teacher's (the
    initialization bottleneck the paper targets). Uses a generous budget so
    per-layer ranks are non-degenerate (at CFG's tiny dims, bpp=2 gives
    rank-1 paths where a 1x1 rotation is a no-op)."""
    cfg = dataclasses.replace(CFG, bpp=6.0)
    student = M.init_student_from_teacher(
        cfg, teacher, "itq", jax.random.PRNGKey(2), itq_iters=20
    )
    s_logits = M.student_logits(cfg, student, tokens[:, :-1])
    t_logits = M.teacher_logits(cfg, teacher, tokens[:, :-1])
    err_init = float(jnp.mean((s_logits - t_logits) ** 2))
    scale = float(jnp.mean(t_logits**2))
    # The fixture teacher is *untrained* (flat spectrum — the worst case for
    # low-rank compression), so demand correlation rather than tight error:
    cos = float(
        jnp.sum(s_logits * t_logits)
        / (jnp.linalg.norm(s_logits) * jnp.linalg.norm(t_logits))
    )
    assert err_init < 1.5 * scale, f"init err {err_init} vs logit scale {scale}"
    assert cos > 0.3, f"student/teacher logit cosine {cos}"


@pytest.mark.parametrize("strategy", ["standard", "rotation", "itq"])
def test_strategies_initialize(teacher, strategy):
    student = M.init_student_from_teacher(
        CFG, teacher, strategy, jax.random.PRNGKey(3), itq_iters=5
    )
    spec = M.student_param_spec(CFG)
    assert len(student) == len(spec)
    for (name, shape), arr in zip(spec, student):
        assert tuple(arr.shape) == tuple(shape), name


def test_itq_init_beats_standard_on_reconstruction(teacher):
    """Per-layer reconstruction: ITQ < standard in MSE (Table 3 at init).
    Uses the wide d_ff layer and a budget giving rank > 1 (a 1x1 rotation
    cannot change sign reconstruction)."""
    t = dict(zip([n for n, _ in M.teacher_param_spec(CFG)], teacher))
    w = t["b0.gate"]
    r = max(dataclasses.replace(CFG, bpp=6.0).rank_for_budget(*w.shape), 8)
    assert r <= min(w.shape)

    def recon_mse(strategy):
        paths = M.compress_layer_init(
            w, r, strategy, jax.random.PRNGKey(4), itq_iters=30
        )
        recon = jnp.zeros_like(w)
        for lat_u, lat_v, h, l, g in paths:
            u_b = jnp.where(lat_u < 0, -1.0, 1.0)
            v_b = jnp.where(lat_v < 0, -1.0, 1.0)
            recon += ((u_b * h[:, None]) * l[None, :]) @ (v_b * g[:, None]).T
        return float(jnp.mean((recon - w) ** 2))

    assert recon_mse("itq") < recon_mse("standard")


def test_teacher_train_step_reduces_loss(teacher, tokens):
    spec = M.teacher_param_spec(CFG)
    m = M.zeros_like_params(spec)
    v = M.zeros_like_params(spec)
    params = teacher
    losses = []
    for step in range(8):
        params, m, v, loss = M.teacher_train_step(
            CFG, params, m, v, jnp.float32(step), tokens, jnp.float32(3e-3)
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_student_train_step_runs_and_counts_flips(teacher, tokens):
    student = M.init_student_from_teacher(
        CFG, teacher, "itq", jax.random.PRNGKey(5), itq_iters=5
    )
    spec = M.student_param_spec(CFG)
    m = M.zeros_like_params(spec)
    v = M.zeros_like_params(spec)
    s2, m, v, loss, flips = M.student_train_step(
        CFG, student, teacher, m, v, jnp.float32(0), tokens, jnp.float32(1e-3)
    )
    assert math.isfinite(float(loss))
    assert float(flips) >= 0
    # Params actually changed.
    changed = any(
        float(jnp.max(jnp.abs(a - b))) > 0 for a, b in zip(student, s2)
    )
    assert changed


def test_ste_gradients_flow_to_latents(teacher, tokens):
    student = M.init_student_from_teacher(
        CFG, teacher, "standard", jax.random.PRNGKey(6), itq_iters=0
    )
    spec = M.student_param_spec(CFG)

    def loss_fn(ps):
        logits = M.student_logits(CFG, ps, tokens[:, :-1])
        return M.next_token_ce(logits, tokens)

    grads = jax.grad(loss_fn)(student)
    lat_grads = [
        g for (n, _), g in zip(spec, grads) if ".lat_" in n
    ]
    nonzero = sum(float(jnp.sum(jnp.abs(g))) > 0 for g in lat_grads)
    # STE must deliver gradient to (almost) every latent tensor.
    assert nonzero >= 0.9 * len(lat_grads)


def test_kd_loss_zero_when_identical():
    logits = jax.random.normal(jax.random.PRNGKey(7), (2, 4, 16))
    assert abs(float(M.kd_loss(logits, logits, 2.0))) < 1e-6


def test_kd_loss_positive_when_different():
    a = jax.random.normal(jax.random.PRNGKey(8), (2, 4, 16))
    b = jax.random.normal(jax.random.PRNGKey(9), (2, 4, 16))
    assert float(M.kd_loss(a, b, 2.0)) > 0


def test_fp_latent_variant():
    cfg = dataclasses.replace(CFG, fp_latent=True, bpp=4.0)
    teacher = M.init_teacher(cfg, jax.random.PRNGKey(10))
    student = M.init_student_from_teacher(
        cfg, teacher, "standard", jax.random.PRNGKey(11)
    )
    toks = jax.random.randint(jax.random.PRNGKey(12), (cfg.batch, cfg.seq), 0, cfg.vocab)
    logits = M.student_logits(cfg, student, toks)
    assert logits.shape == (cfg.batch, cfg.seq, cfg.vocab)
    # FP ranks must be ~16x smaller than binary ranks at the same budget.
    r_fp = cfg.rank_for_budget(88, 32)
    r_bin = CFG.rank_for_budget(88, 32)  # bpp=2.0 binary
    assert r_fp * 4 < r_bin * (4.0 / 2.0) * 16


def test_pallas_and_ref_student_forward_agree(teacher):
    student = M.init_student_from_teacher(
        CFG, teacher, "itq", jax.random.PRNGKey(13), itq_iters=3
    )
    toks = jax.random.randint(jax.random.PRNGKey(14), (1, 8), 0, CFG.vocab)
    a = M.student_logits(CFG, student, toks, use_pallas=False)
    b = M.student_logits(CFG, student, toks, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
