"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis drives the shape/seed sweep — the CORE correctness signal for
the compute hot-path (system prompt contract: L1 kernels == ref.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.binarize import binarize
from compile.kernels.itq_step import itq_iteration, sign_project
from compile.kernels.tri_scale import (
    mxu_utilization_estimate,
    tri_scale_matmul,
    vmem_bytes,
)

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape)


# ---------------------------------------------------------------------------
# tri_scale_matmul
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 17),
    d_in=st.integers(3, 200),
    d_out=st.integers(3, 300),
    r=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_tri_scale_matches_ref(b, d_in, d_out, r, seed):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 6)
    x = jax.random.normal(ks[0], (b, d_in))
    u_b = jnp.sign(jax.random.normal(ks[1], (d_out, r))) + 0.0
    v_b = jnp.sign(jax.random.normal(ks[2], (d_in, r))) + 0.0
    h = jax.random.uniform(ks[3], (d_out,), minval=0.1, maxval=2.0)
    l = jax.random.uniform(ks[4], (r,), minval=0.01, maxval=1.0)
    g = jax.random.uniform(ks[5], (d_in,), minval=0.1, maxval=2.0)
    got = tri_scale_matmul(x, u_b, v_b, h, l, g)
    want = ref.tri_scale_matmul_ref(x, u_b, v_b, h, l, g)
    # Tile-local vs full-row accumulation order differs → ~1e-3 relative
    # f32 slack at large output magnitudes.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


def test_tri_scale_1d_input():
    x = rand(0, (40,))
    u_b = jnp.sign(rand(1, (30, 8)))
    v_b = jnp.sign(rand(2, (40, 8)))
    h, l, g = jnp.ones((30,)), jnp.ones((8,)), jnp.ones((40,))
    got = tri_scale_matmul(x, u_b, v_b, h, l, g)
    assert got.shape == (30,)
    want = ref.tri_scale_matmul_ref(x, u_b, v_b, h, l, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_tri_scale_exact_tile_multiples():
    # Shapes exactly at TILE boundaries (no padding path).
    x = rand(3, (8, 128))
    u_b = jnp.sign(rand(4, (256, 16)))
    v_b = jnp.sign(rand(5, (128, 16)))
    h, l, g = jnp.ones((256,)), jnp.ones((16,)), jnp.ones((128,))
    got = tri_scale_matmul(x, u_b, v_b, h, l, g)
    want = ref.tri_scale_matmul_ref(x, u_b, v_b, h, l, g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_perf_model_estimates_positive():
    assert vmem_bytes(4096, 4096, 128) > 0
    assert 0.0 < mxu_utilization_estimate(4096, 4096, 64) <= 1.0
    assert mxu_utilization_estimate(4096, 4096, 256) == 1.0


# ---------------------------------------------------------------------------
# binarize
# ---------------------------------------------------------------------------


@given(
    n=st.integers(1, 300),
    r=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_binarize_matches_ref(n, r, seed):
    u = jax.random.normal(jax.random.PRNGKey(seed), (n, r))
    s, a = binarize(u)
    rs, ra = ref.binarize_ref(u)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(rs))
    np.testing.assert_allclose(np.asarray(a), np.asarray(ra), rtol=1e-6)


def test_binarize_alpha_is_optimal():
    # Perturbing alpha must not reduce ||u - alpha*s||^2 (Lemma 4.2).
    u = rand(7, (32, 16))
    s, a = binarize(u)

    def err(alpha):
        return float(jnp.sum((u - alpha[:, None] * s) ** 2))

    base = err(a)
    assert err(a * 1.05) >= base
    assert err(a * 0.95) >= base


def test_binarize_zero_rows():
    u = jnp.zeros((4, 8))
    s, a = binarize(u)
    np.testing.assert_array_equal(np.asarray(a), np.zeros(4))
    np.testing.assert_array_equal(np.asarray(s), np.ones((4, 8)))


# ---------------------------------------------------------------------------
# itq_step
# ---------------------------------------------------------------------------


@given(
    n=st.integers(4, 400),
    r=st.integers(2, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_sign_project_matches_ref(n, r, seed):
    k = jax.random.PRNGKey(seed)
    z = jax.random.normal(k, (n, r))
    rot, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(seed + 1), (r, r)))
    b, mass = sign_project(z, rot)
    rb = ref.itq_sign_project_ref(z, rot)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(rb))
    want_mass = float(jnp.sum(jnp.abs(z @ rot)))
    assert abs(float(mass) - want_mass) < 1e-2 * max(want_mass, 1.0)


def test_itq_iteration_monotone_l1():
    # App. A.2: each alternation is non-decreasing in ||ZR||_1.
    z = rand(11, (500, 12))
    rot, _ = jnp.linalg.qr(rand(12, (12, 12)))
    masses = []
    for _ in range(25):
        rot, mass = itq_iteration(z, rot)
        masses.append(float(mass))
    for a, b in zip(masses, masses[1:]):
        assert b >= a - 1e-3 * abs(a)


def test_itq_iteration_preserves_orthogonality():
    z = rand(13, (200, 10))
    rot, _ = jnp.linalg.qr(rand(14, (10, 10)))
    for _ in range(10):
        rot, _ = itq_iteration(z, rot)
    defect = float(jnp.max(jnp.abs(rot @ rot.T - jnp.eye(10))))
    assert defect < 1e-4


def test_itq_reduces_distortion_vs_random():
    z = rand(15, (600, 16), scale=1.0)
    # Make z spiky: zero most entries.
    mask = jax.random.bernoulli(jax.random.PRNGKey(16), 0.1, z.shape)
    z = jnp.where(mask, z * 5.0, z * 0.05)
    rot0, _ = jnp.linalg.qr(rand(17, (16, 16)))
    lam0 = float(jnp.mean(ref.local_distortion_ref(z @ rot0)))
    rot = rot0
    for _ in range(50):
        rot, _ = itq_iteration(z, rot)
    lam = float(jnp.mean(ref.local_distortion_ref(z @ rot)))
    assert lam < lam0, f"{lam} !< {lam0}"
